"""Training launcher.

    python -m repro.launch.train --arch llama3_2_1b --steps 200 \
        --parallel auto --devices 256
    python -m repro.launch.train --arch smollm_360m --parallel dp=2,mp=2 \
        --reduced --steps 100

``--parallel auto`` invokes the paper's HybridPlanner (Eq. 6 crossover logic)
to factor the device budget into DP x MP; explicit dp=/mp= overrides.  On this
CPU container use ``--reduced`` (small configs, 1-device mesh) — the full mesh
path is exercised by launch/dryrun.py.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import INPUT_SHAPES, get_config
from repro.core.planner import HybridPlanner, default_epoch_model
from repro.data import DataPipeline, make_lm_dataset
from repro.launch.mesh import make_host_mesh, make_mesh
from repro.models.api import build_model
from repro.optim import adamw, warmup_cosine
from repro.parallel.plan import ParallelPlan
from repro.train.loop import LoopConfig, train_loop
from repro.train.steps import (TrainState, _make_pctx, init_train_state,
                               make_train_step, shardings_for)


def parse_parallel(spec: str, devices: int, cfg) -> ParallelPlan:
    if spec == "auto":
        planner = HybridPlanner(cfg, epoch_model=default_epoch_model(cfg))
        choice = planner.best(devices)
        print(f"[planner] {choice.mesh_shape} SU={choice.speedup:.1f} "
              f"(SU^M={choice.su_m:.2f}, SE_N={choice.se_n:.3f}, "
              f"E1/EN={choice.epochs_ratio:.3f})")
        return choice.plan
    kv = dict(p.split("=") for p in spec.split(","))
    mp = int(kv.get("mp", 1))
    return ParallelPlan(dp_axes=("data",),
                        model_axis="model" if mp > 1 else None,
                        microbatches=int(kv.get("accum", 1)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--parallel", default="dp=1,mp=1")
    ap.add_argument("--devices", type=int, default=len(jax.devices()))
    ap.add_argument("--reduced", action="store_true",
                    help="2-layer small config (CPU)")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    plan = parse_parallel(args.parallel, args.devices, cfg)
    api = build_model(cfg)
    data = make_lm_dataset(vocab=min(cfg.vocab_size, 64), seq_len=args.seq)
    print(f"[data] markov-lm entropy floor = {data.entropy:.4f} nats/token")

    opt = adamw(warmup_cosine(args.lr, 20, args.steps))
    mesh = make_host_mesh()
    pctx = None
    train_step = make_train_step(api, opt, mesh=mesh, plan=plan, pctx=pctx)
    state = init_train_state(api, opt, jax.random.PRNGKey(0))
    train_step = jax.jit(train_step, donate_argnums=(0,))

    def epoch_fn(e):
        def gen():
            for b in data.epoch(e, args.batch):
                if cfg.family in ("cnn",):
                    continue
                yield {"tokens": b["tokens"].astype(np.int32),
                       "labels": b["labels"].astype(np.int32)}
        return gen()

    pipeline = DataPipeline(epoch_fn)
    summary = train_loop(train_step, state, pipeline,
                         LoopConfig(total_steps=args.steps,
                                    ckpt_every=100 if args.ckpt_dir else 0,
                                    ckpt_dir=args.ckpt_dir))
    print(f"[done] steps={summary['steps']} final_loss="
          f"{summary['final_loss']:.4f} wall={summary['wall_s']:.1f}s "
          f"(floor {data.entropy:.4f})")


if __name__ == "__main__":
    main()
