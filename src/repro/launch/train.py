"""Training launcher.

    python -m repro.launch.train --arch llama3_2_1b --steps 200 \
        --parallel auto --devices 256
    python -m repro.launch.train --arch biglstm --parallel auto --reduced
    python -m repro.launch.train --arch smollm_360m --parallel dp=2,mp=2 \
        --reduced --steps 100
    python -m repro.launch.train --arch biglstm --parallel pipe=2,micro=4 \
        --reduced
    python -m repro.launch.train --arch llama3_2_1b --parallel dp=2,mp=2 \
        --reduced --comm-runtime overlapped --comm-chunks 2
    python -m repro.launch.train --arch llama3_2_1b --parallel dp=2,cp=4 \
        --reduced --seq 64          # context parallelism: ppermute KV ring

``--parallel auto`` invokes the paper's HybridPlanner — the unified search
over DP x tensor-MP x pipeline-MP x schedule factorizations of the device
budget (``--devices``, default 256) — and *executes* the winning plan:
pipeline plans run through ``parallel.pipeline.pipeline_apply`` on a
**dp x stages mesh** — the model axis carries the stages, the data axis
carries as much of the projected DP degree as the local machine affords
(capped by ``--max-local-devices``, default 8, on CPU), with the batch
sharded over it and the gradient all-reduce inserted by GSPMD.  On CPU the
launcher forces dp*stages host devices before jax initializes.  Explicit
``dp=/mp=/accum=``, ``pipe=/micro=/sched=/v=/dp=``, or ``dp=/cp=`` specs
override the search (``cp=`` = context parallelism: the model axis carries
the sequence-sharded ppermute KV ring of ``parallel.context`` with params
replicated across it; ``--context-parallel`` restricts ``auto`` to those
points).  ``--reduced`` shrinks the arch (2 layers, small dims) for the CPU
container.

Tensor-MP and multi-DP plans likewise execute on a real local dp x mp mesh
(forced host devices on CPU); ``--comm-runtime overlapped`` selects the
overlap-scheduled collective runtime (``parallel.collectives``: chunked
collective-matmul rings for the Megatron matmuls, bucketed reduce-scatter
DP grad sync), ``gspmd`` being the monolithic-collective escape hatch.

Fault tolerance: ``--ckpt-dir``/``--ckpt-every`` write CRC-manifested
checkpoints (``--keep-last`` retention, ``--background-save`` off the step
path) with a guaranteed final checkpoint; ``--resume`` restores the newest
*valid* one — re-sharded onto the current mesh, so a 16-way-DP run resumes
on 8 or 32 devices — and continues with exact data order.  ``--fault``
injects a deterministic failure schedule (``train.fault``), ``--max-retries``
bounds in-place step retries, ``--max-restarts`` runs the whole loop under
the checkpoint-restoring supervisor, ``--watchdog`` flags hung steps:

    python -m repro.launch.train --arch llama3_2_1b --reduced --steps 30 \\
        --ckpt-dir /tmp/ck --ckpt-every 10 --fault "kill@25"   # preempted
    python -m repro.launch.train --arch llama3_2_1b --reduced --steps 30 \\
        --ckpt-dir /tmp/ck --resume                            # recovers
"""
from __future__ import annotations

import argparse
import dataclasses
import os

from repro.configs import INPUT_SHAPES, get_config
from repro.core.planner import HybridPlanner, default_epoch_model
from repro.parallel.plan import ParallelPlan


def parse_parallel(spec: str, devices: int, cfg, comm_runtime: str = "gspmd",
                   context_parallel: bool = False):
    """Resolve a --parallel spec to (plan, mp_degree, dp_hint).

    ``dp_hint`` is the projected DP degree the launcher should realize (the
    planner's pods*dp, or an explicit ``dp=`` key); the executable mesh
    clamps it to the local machine.  Pure planning — no jax device access,
    so the launcher can still force host devices afterwards for pipeline
    execution.  ``comm_runtime`` keys the auto search's overlap terms (the
    planner stamps each point with the runtime that will actually carry it).
    ``context_parallel`` restricts the auto search to context-parallel
    points (sequence-sharded KV rings) and reinterprets an explicit ``mp=``
    degree as the ring size; ``cp=N`` in the spec selects it directly.
    """
    from repro.models.api import supports_pipeline

    if spec == "auto":
        planner = HybridPlanner(cfg, epoch_model=default_epoch_model(cfg),
                                comm_runtime=comm_runtime)
        choices = planner.choices(devices)
        if context_parallel:
            choices = [c for c in choices if c.mp_kind == "context"]
            if not choices:
                raise SystemExit(
                    f"[planner] no memory-feasible context-parallel strategy "
                    f"for {cfg.name} at {devices} devices (needs the dense "
                    f"decoder CP path and a ring that divides the sequence)")
        if not choices:
            raise SystemExit(f"[planner] no memory-feasible strategy for "
                             f"{cfg.name} at {devices} devices")
        choice = next((c for c in choices if c.mp_kind != "pipeline"
                       or supports_pipeline(cfg)), None)
        if choice is None:
            choice = choices[0]
        if choice is not choices[0]:
            print(f"[planner] best plan ({choices[0].mp_kind}) lacks runtime "
                  f"support for {cfg.name}; using next feasible choice")
        print(f"[planner] {choice.mesh_shape} kind={choice.mp_kind} "
              f"sched={choice.schedule} micro={choice.microbatches} "
              f"SU={choice.speedup:.1f} "
              f"(SU^M={choice.su_m:.2f}, SE_N={choice.se_n:.3f}, "
              f"E1/EN={choice.epochs_ratio:.3f}, "
              f"mem={choice.mem_bytes / 2**30:.2f} GiB)")
        return choice.plan, choice.mp, choice.pods * choice.dp
    kv = dict(p.split("=") for p in spec.split(","))
    pipe = int(kv.get("pipe", 0))
    cp = int(kv.get("cp", 0))
    if context_parallel and cp <= 1:
        cp = int(kv.pop("mp", 0))         # --context-parallel: mp= is the ring
    if cp > 1:
        if pipe > 1 or int(kv.get("mp", 1)) > 1:
            raise SystemExit(
                "[plan] cp= is its own model axis: it cannot combine with "
                "mp= (tensor) or pipe= (pipeline) in one spec")
        plan = ParallelPlan(dp_axes=("data",), model_axis="model",
                            mp_kind="context",
                            microbatches=int(kv.get("accum", 1)))
        return plan, cp, int(kv.get("dp", 1))
    if pipe > 1:
        sched = kv.get("sched", "gpipe")
        v = int(kv.get("v", 2 if sched == "interleaved" else 1))
        if (sched == "interleaved") != (v > 1):
            raise SystemExit(
                f"[plan] sched={sched} incompatible with v={v} "
                f"(interleaved needs v>=2; gpipe/1f1b take v=1)")
        plan = ParallelPlan(dp_axes=("data",), model_axis="model",
                            mp_kind="pipeline",
                            microbatches=int(kv.get("micro", 4)),
                            schedule=sched, virtual_stages=v)
        return plan, pipe, int(kv.get("dp", 1))
    mp = int(kv.get("mp", 1))
    plan = ParallelPlan(dp_axes=("data",),
                        model_axis="model" if mp > 1 else None,
                        microbatches=int(kv.get("accum", 1)))
    return plan, mp, int(kv.get("dp", 1))


def _ensure_host_devices(n: int):
    """Force ``n`` host platform devices — must run before jax initializes
    its backend (which is why main() defers every jax call until after the
    plan is known)."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={n}").strip()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--parallel", default="",
                    help="'auto', 'dp=2,mp=2', 'pipe=2,micro=4', "
                         "'dp=2,cp=4', ... (default: dp=1,mp=1 — except "
                         "with --resume, where an empty spec re-runs the "
                         "planner for the CURRENT device count: an elastic "
                         "grow/shrink resume must not need the old spec "
                         "replayed)")
    ap.add_argument("--devices", type=int, default=0,
                    help="planner device budget for --parallel auto "
                         "(default: 256, the single-pod production budget)")
    ap.add_argument("--reduced", action="store_true",
                    help="2-layer small config (CPU)")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=100,
                    help="checkpoint cadence in steps (with --ckpt-dir); a "
                         "final checkpoint at loop exit is guaranteed either "
                         "way")
    ap.add_argument("--keep-last", type=int, default=0,
                    help="retain only the N newest checkpoints (0 = all)")
    ap.add_argument("--background-save", action="store_true",
                    help="serialize + write checkpoints on a worker thread, "
                         "off the step critical path")
    ap.add_argument("--resume", action="store_true",
                    help="restore the newest VALID checkpoint under "
                         "--ckpt-dir (corrupt files are skipped with a "
                         "warning) and continue with exact data order; the "
                         "checkpoint re-shards onto the current mesh, so a "
                         "run saved at one DP degree resumes on another "
                         "(elastic grow/shrink)")
    ap.add_argument("--max-retries", type=int, default=0,
                    help="bounded in-place retries per failed step "
                         "(exponential backoff)")
    ap.add_argument("--max-restarts", type=int, default=0,
                    help="> 0: run under the fault supervisor — a crashed "
                         "attempt restarts from the newest valid checkpoint "
                         "up to N times")
    ap.add_argument("--watchdog", type=float, default=0.0,
                    help="> 0: flag (log + count) steps exceeding this many "
                         "seconds")
    ap.add_argument("--fault", default="",
                    help="deterministic fault-injection schedule, e.g. "
                         "'fail@5x2,kill@7,corrupt@10:bitflip,stall@3:0.4' "
                         "(see repro.train.fault)")
    ap.add_argument("--max-local-devices", type=int, default=8,
                    help="cap on forced host devices for dp x stages "
                         "pipeline execution on CPU")
    ap.add_argument("--pipe-runtime", choices=["scheduled", "ad"],
                    default=None,
                    help="pipeline runtime escape hatch: 'scheduled' "
                         "(default) hand-executes the full fwd+bwd WorkUnit "
                         "table and realizes the schedule's activation "
                         "residency; 'ad' keeps jax.grad through the "
                         "forward scan (GPipe-like memory) for bit-for-bit "
                         "differential testing")
    ap.add_argument("--comm-runtime", choices=["gspmd", "overlapped"],
                    default=None,
                    help="collective runtime for tensor-MP matmuls and the "
                         "DP gradient sync: 'overlapped' routes the Megatron "
                         "row/column matmuls through the chunked "
                         "collective-matmul ppermute rings and the grad "
                         "exchange through the bucketed reduce-scatter sync "
                         "(parallel.collectives); 'gspmd' (default) leaves "
                         "both to the partitioner's monolithic collectives")
    ap.add_argument("--comm-chunks", type=int, default=None,
                    help="ring chunks per shard for --comm-runtime "
                         "overlapped (default 1; more chunks = finer "
                         "overlap, more per-hop latency)")
    ap.add_argument("--context-parallel", action="store_true",
                    help="context parallelism: shard the SEQUENCE axis over "
                         "the model axis and run attention as a ppermute KV "
                         "ring (parallel.context); with --parallel auto "
                         "restricts the search to context plans, with an "
                         "explicit spec reinterprets mp= as the ring size "
                         "(or use --parallel dp=2,cp=4 directly)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.family == "cnn":
        # the CLI feeds the token-LM pipeline; a cnn arch would yield zero
        # batches per epoch and spin forever
        raise SystemExit(f"[data] {cfg.name}: the train CLI drives the "
                         f"token-LM data pipeline; cnn archs train through "
                         f"benchmarks/fig4_epochs.py")
    if args.resume and not args.ckpt_dir:
        raise SystemExit("[resume] --resume needs --ckpt-dir")
    spec = args.parallel
    budget = args.devices or 256
    if spec == "auto" and args.resume:
        # elastic resume replan: the checkpoint stores global leaves and
        # re-shards onto whatever mesh this process has, so the PLAN comes
        # from the planner at the CURRENT local device budget — the old
        # run's --parallel spec never needs replaying after a grow/shrink
        budget = args.devices or args.max_local_devices
        print(f"[plan] --parallel auto with --resume: re-running the "
              f"planner for the current {budget}-device budget")
    if not spec:
        # a bare --resume keeps the same default plan a fresh run gets:
        # same-topology kill/resume must stay bit-reproducible (pinned in
        # tests/test_fault.py) — elastic replanning is an explicit opt-in
        # via --parallel auto
        spec = "dp=1,mp=1"
    plan, mp, dp_hint = parse_parallel(spec, budget, cfg,
                                       comm_runtime=args.comm_runtime
                                       or "gspmd",
                                       context_parallel=args.context_parallel)
    if plan.mp_kind == "context" and mp > 1:
        if args.seq % mp:
            raise SystemExit(
                f"[plan] context parallelism shards the sequence: --seq "
                f"({args.seq}) must divide by the {mp}-way ring")
        if args.comm_runtime == "overlapped" or args.comm_chunks:
            raise SystemExit(
                "[plan] --comm-runtime/--comm-chunks do not apply to "
                "context-parallel plans (the KV ring IS the comm schedule)")
    if args.pipe_runtime:
        if not plan.is_pipeline:
            raise SystemExit("[plan] --pipe-runtime only applies to pipeline "
                             "plans (--parallel pipe=... or a planner choice "
                             "with kind=pipeline)")
        plan = dataclasses.replace(plan, runtime=args.pipe_runtime)
    if args.comm_runtime or args.comm_chunks:
        if args.comm_chunks and (args.comm_runtime or plan.comm_runtime) \
                != "overlapped":
            raise SystemExit("[plan] --comm-chunks only applies with "
                             "--comm-runtime overlapped")
        if plan.is_pipeline and mp > 1:
            if spec != "auto":
                raise SystemExit(
                    "[plan] --comm-runtime/--comm-chunks apply to tensor-MP "
                    "/ DP plans; pipeline stages exchange activations over "
                    "their own ppermute rings (see --pipe-runtime)")
            # planner chose pipeline: the collective runtime is inert there
            print("[plan] note: planner chose a pipeline plan; "
                  "--comm-runtime/--comm-chunks do not apply to it")
        else:
            # auto plans already carry the planner's per-point runtime stamp
            # (gspmd for archs the overlapped runtime cannot execute)
            plan = dataclasses.replace(
                plan,
                comm_runtime=(plan.comm_runtime if spec == "auto"
                              else (args.comm_runtime or plan.comm_runtime)),
                comm_chunks=args.comm_chunks or plan.comm_chunks)

    # Pipeline plans need a real mesh axis with one device per stage plus as
    # much of the projected DP degree as fits locally; size the executable
    # dp x stages mesh to the local machine, then (on CPU) force that many
    # host devices BEFORE any jax backend init below.  Tensor-MP / multi-DP
    # plans likewise get a real local dp x mp mesh (capped by
    # --max-local-devices) so the collective runtime selected by
    # --comm-runtime actually executes.
    pipeline = plan.is_pipeline and mp > 1
    spmd = (not pipeline) and (mp > 1 or dp_hint > 1)
    dp = 1

    def clamp_dp(what: str) -> int:
        """Realize as much of the projected DP degree as the local budget
        affords; dp must divide the batch (it is sharded over "data")."""
        dp_cap = min(max(dp_hint, 1), max(1, args.max_local_devices // mp))
        got = max(d for d in range(1, dp_cap + 1) if args.batch % d == 0)
        if got < dp_hint:
            print(f"[plan] clamped DP {dp_hint} -> {got} "
                  f"(local budget {args.max_local_devices}, {what})")
        return got

    if spmd:
        dp = clamp_dp(f"{mp}-way MP")
        _ensure_host_devices(dp * mp)
    if pipeline:
        from repro.models.api import pipeline_applicable
        if not pipeline_applicable(cfg, mp, plan.virtual_stages):
            raise SystemExit(
                f"[plan] {cfg.name}: {mp} pipeline stages (x{max(plan.virtual_stages, 1)} "
                f"chunks) need a supported arch with n_layers % (stages*v) "
                f"== 0 (n_layers={cfg.n_layers})")
        dp = clamp_dp(f"{mp} stages")
        # the planner models micro-batches against its reference batch; the
        # executed run must use a count that divides the per-dp-shard batch
        shard_b = args.batch // dp
        micro = max(k for k in range(1, min(plan.microbatches, shard_b) + 1)
                    if shard_b % k == 0)
        if micro != plan.microbatches:
            print(f"[plan] clamped micro-batches {plan.microbatches} -> "
                  f"{micro} (batch={args.batch}, dp={dp})")
            plan = dataclasses.replace(plan, microbatches=micro)
        _ensure_host_devices(dp * mp)

    import jax
    import numpy as np

    from repro.data import DataPipeline, make_lm_dataset
    from repro.launch.mesh import make_host_mesh, make_mesh
    from repro.models.api import build_model
    from repro.optim import adamw, warmup_cosine
    from repro.parallel.jaxcompat import set_mesh
    from repro.train.loop import LoopConfig, train_loop
    from repro.train.steps import (_make_pctx, eval_train_state,
                                   init_train_state, make_train_step,
                                   shardings_for)

    if pipeline or spmd:
        if jax.device_count() < dp * mp:
            raise SystemExit(f"[mesh] plan needs {dp * mp} devices, "
                             f"have {jax.device_count()} "
                             f"(jax initialized early?)")
        mesh = make_mesh(dp=dp, mp=mp)
        # DP narrows to the local mesh's data axis: drop pod axes / fsdp
        # from the projected plan, keep stages + schedule + micro-batches
        plan = dataclasses.replace(plan, dp_axes=("data",), fsdp_axes=())
    else:
        mesh = make_host_mesh()
        plan = dataclasses.replace(plan, dp_axes=("data",), fsdp_axes=())
    print(f"[plan] {plan.describe(mesh)}")

    api = build_model(cfg)
    data = make_lm_dataset(vocab=min(cfg.vocab_size, 64), seq_len=args.seq)
    print(f"[data] markov-lm entropy floor = {data.entropy:.4f} nats/token")

    opt = adamw(warmup_cosine(args.lr, 20, args.steps))
    pctx = _make_pctx(mesh, plan, batch_shardable=dp > 1) if spmd else None
    train_step = make_train_step(api, opt, mesh=mesh, plan=plan, pctx=pctx)
    state = init_train_state(api, opt, jax.random.PRNGKey(0))
    state_sh = None
    if pipeline and dp > 1:
        # dp x stages: batch sharded over the data axis, params/opt
        # replicated — GSPMD inserts the gradient all-reduce over "data"
        from jax.sharding import NamedSharding, PartitionSpec as P
        state_sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), state)
        batch_sh = {"tokens": NamedSharding(mesh, P("data", None)),
                    "labels": NamedSharding(mesh, P("data", None))}
        train_step = jax.jit(train_step, donate_argnums=(0,),
                             in_shardings=(state_sh, batch_sh))
    elif spmd:
        # tensor-MP / multi-DP: params via ShardingRules (Megatron
        # column/row specs on the model axis), batch over the data axis;
        # the comm runtime selected on the plan decides whether GSPMD or
        # parallel.collectives carries the resulting collectives
        i32 = jax.numpy.int32
        specs = {"tokens": jax.ShapeDtypeStruct((args.batch, args.seq), i32),
                 "labels": jax.ShapeDtypeStruct((args.batch, args.seq), i32)}
        state_sh, batch_sh = shardings_for(api, mesh, plan, opt, specs)
        train_step = jax.jit(train_step, donate_argnums=(0,),
                             in_shardings=(state_sh, batch_sh))
    else:
        train_step = jax.jit(train_step, donate_argnums=(0,))

    def epoch_fn(e):
        def gen():
            for b in data.epoch(e, args.batch):
                yield {"tokens": b["tokens"].astype(np.int32),
                       "labels": b["labels"].astype(np.int32)}
        return gen()

    pipeline_data = DataPipeline(
        epoch_fn, steps_per_epoch=data.steps_per_epoch(args.batch))
    loop_cfg = LoopConfig(total_steps=args.steps,
                          ckpt_every=args.ckpt_every if args.ckpt_dir else 0,
                          ckpt_dir=args.ckpt_dir,
                          keep_last=args.keep_last,
                          background_save=args.background_save,
                          max_retries=args.max_retries,
                          watchdog_timeout_s=args.watchdog)

    # fault-injection harness: wraps the (jitted) step; the on_checkpoint
    # hook lets the schedule corrupt just-written checkpoints
    on_ckpt = None
    if args.fault:
        from repro.train.fault import FaultInjector, parse_fault_schedule
        injector = FaultInjector(parse_fault_schedule(args.fault))
        train_step = injector.wrap_step(train_step)
        on_ckpt = injector.after_save

    # elastic resume: the checkpoint stores global (unsharded) leaves, so
    # device_put against the CURRENT mesh's shardings re-shards a run saved
    # at any DP degree onto this one
    if args.resume:
        from repro.checkpoint import restore_latest_valid
        restored, fname = restore_latest_valid(
            args.ckpt_dir, eval_train_state(api, opt), state_sh)
        if restored is not None:
            state = restored
            print(f"[resume] restored {os.path.basename(fname)} at step "
                  f"{int(jax.device_get(state.step))} onto {dp}-way DP "
                  f"x {mp}-way MP")
        else:
            print("[resume] no valid checkpoint found; starting fresh")

    with set_mesh(mesh):
        if args.max_restarts > 0:
            from repro.train.fault import run_supervised
            summary = run_supervised(
                train_step, pipeline_data, loop_cfg,
                init_fn=lambda: init_train_state(api, opt,
                                                 jax.random.PRNGKey(0)),
                like=eval_train_state(api, opt), shardings=state_sh,
                max_restarts=args.max_restarts, on_checkpoint=on_ckpt)
        else:
            summary = train_loop(train_step, state, pipeline_data, loop_cfg,
                                 on_checkpoint=on_ckpt)
    flags = "".join(
        f" {k}={summary[k]}" for k in ("retries", "hangs", "restarts")
        if summary.get(k))
    print(f"[done] steps={summary['steps']} final_loss="
          f"{summary['final_loss']:.4f} wall={summary['wall_s']:.1f}s "
          f"(floor {data.entropy:.4f}){flags}")


if __name__ == "__main__":
    main()
