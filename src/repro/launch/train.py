"""Training launcher.

    python -m repro.launch.train --arch llama3_2_1b --steps 200 \
        --parallel auto --devices 256
    python -m repro.launch.train --arch biglstm --parallel auto --reduced
    python -m repro.launch.train --arch smollm_360m --parallel dp=2,mp=2 \
        --reduced --steps 100
    python -m repro.launch.train --arch biglstm --parallel pipe=2,micro=4 \
        --reduced

``--parallel auto`` invokes the paper's HybridPlanner — the 3-way search over
DP x tensor-MP x pipeline-MP factorizations of the device budget (``--devices``,
default 256) — and *executes* the winning plan: pipeline plans run through
``parallel.pipeline.pipeline_apply`` on a mesh whose model axis carries the
stages (on CPU the launcher forces that many host devices before jax
initializes).  Explicit ``dp=/mp=/accum=`` or ``pipe=/micro=`` specs override
the search.  ``--reduced`` shrinks the arch (2 layers, small dims) for the
CPU container.
"""
from __future__ import annotations

import argparse
import dataclasses
import os

from repro.configs import INPUT_SHAPES, get_config
from repro.core.planner import HybridPlanner, default_epoch_model
from repro.parallel.plan import ParallelPlan


def parse_parallel(spec: str, devices: int, cfg):
    """Resolve a --parallel spec to (plan, mp_degree).

    Pure planning — no jax device access, so the launcher can still force
    host devices afterwards for pipeline execution.
    """
    from repro.models.api import supports_pipeline

    if spec == "auto":
        planner = HybridPlanner(cfg, epoch_model=default_epoch_model(cfg))
        choices = planner.choices(devices)
        if not choices:
            raise SystemExit(f"[planner] no memory-feasible strategy for "
                             f"{cfg.name} at {devices} devices")
        choice = next((c for c in choices if c.mp_kind != "pipeline"
                       or supports_pipeline(cfg)), None)
        if choice is None:
            choice = choices[0]
        if choice is not choices[0]:
            print(f"[planner] best plan ({choices[0].mp_kind}) lacks runtime "
                  f"support for {cfg.name}; using next feasible choice")
        print(f"[planner] {choice.mesh_shape} kind={choice.mp_kind} "
              f"micro={choice.microbatches} SU={choice.speedup:.1f} "
              f"(SU^M={choice.su_m:.2f}, SE_N={choice.se_n:.3f}, "
              f"E1/EN={choice.epochs_ratio:.3f}, "
              f"mem={choice.mem_bytes / 2**30:.2f} GiB)")
        return choice.plan, choice.mp
    kv = dict(p.split("=") for p in spec.split(","))
    pipe = int(kv.get("pipe", 0))
    if pipe > 1:
        plan = ParallelPlan(dp_axes=("data",), model_axis="model",
                            mp_kind="pipeline",
                            microbatches=int(kv.get("micro", 4)))
        return plan, pipe
    mp = int(kv.get("mp", 1))
    plan = ParallelPlan(dp_axes=("data",),
                        model_axis="model" if mp > 1 else None,
                        microbatches=int(kv.get("accum", 1)))
    return plan, mp


def _ensure_host_devices(n: int):
    """Force ``n`` host platform devices — must run before jax initializes
    its backend (which is why main() defers every jax call until after the
    plan is known)."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={n}").strip()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--parallel", default="dp=1,mp=1")
    ap.add_argument("--devices", type=int, default=0,
                    help="planner device budget for --parallel auto "
                         "(default: 256, the single-pod production budget)")
    ap.add_argument("--reduced", action="store_true",
                    help="2-layer small config (CPU)")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    budget = args.devices or 256
    plan, mp = parse_parallel(args.parallel, budget, cfg)

    # Pipeline plans need a real mesh axis with one device per stage; size
    # the executable stage count to the local machine, then (on CPU) force
    # that many host devices BEFORE any jax backend init below.
    pipeline = plan.is_pipeline and mp > 1
    if pipeline:
        from repro.models.api import pipeline_applicable
        if not pipeline_applicable(cfg, mp):
            raise SystemExit(
                f"[plan] {cfg.name}: {mp} pipeline stages need a supported "
                f"arch with n_layers % stages == 0 (n_layers={cfg.n_layers})")
        # the planner models micro-batches against its reference batch; the
        # executed run must use a count that divides the actual --batch
        micro = max(k for k in range(1, min(plan.microbatches, args.batch) + 1)
                    if args.batch % k == 0)
        if micro != plan.microbatches:
            print(f"[plan] clamped micro-batches {plan.microbatches} -> "
                  f"{micro} (batch={args.batch})")
            plan = dataclasses.replace(plan, microbatches=micro)
        _ensure_host_devices(mp)

    import jax
    import numpy as np

    from repro.data import DataPipeline, make_lm_dataset
    from repro.launch.mesh import make_host_mesh, make_mesh
    from repro.models.api import build_model
    from repro.optim import adamw, warmup_cosine
    from repro.parallel.jaxcompat import set_mesh
    from repro.train.loop import LoopConfig, train_loop
    from repro.train.steps import (init_train_state, make_train_step)

    if pipeline:
        if jax.device_count() < mp:
            raise SystemExit(f"[mesh] pipeline plan needs {mp} devices, have "
                             f"{jax.device_count()} (jax initialized early?)")
        mesh = make_mesh(dp=1, mp=mp)
        # DP collapses to the local mesh: drop pod axes / fsdp from the
        # projected plan, keep the pipeline stages + micro-batch count
        plan = dataclasses.replace(plan, dp_axes=("data",), fsdp_axes=())
    else:
        mesh = make_host_mesh()
        plan = dataclasses.replace(plan, dp_axes=("data",), fsdp_axes=())
    print(f"[plan] {plan.describe(mesh)}")

    api = build_model(cfg)
    data = make_lm_dataset(vocab=min(cfg.vocab_size, 64), seq_len=args.seq)
    print(f"[data] markov-lm entropy floor = {data.entropy:.4f} nats/token")

    opt = adamw(warmup_cosine(args.lr, 20, args.steps))
    pctx = None
    train_step = make_train_step(api, opt, mesh=mesh, plan=plan, pctx=pctx)
    state = init_train_state(api, opt, jax.random.PRNGKey(0))
    train_step = jax.jit(train_step, donate_argnums=(0,))

    def epoch_fn(e):
        def gen():
            for b in data.epoch(e, args.batch):
                if cfg.family in ("cnn",):
                    continue
                yield {"tokens": b["tokens"].astype(np.int32),
                       "labels": b["labels"].astype(np.int32)}
        return gen()

    pipeline_data = DataPipeline(epoch_fn)
    with set_mesh(mesh):
        summary = train_loop(train_step, state, pipeline_data,
                             LoopConfig(total_steps=args.steps,
                                        ckpt_every=100 if args.ckpt_dir else 0,
                                        ckpt_dir=args.ckpt_dir))
    print(f"[done] steps={summary['steps']} final_loss="
          f"{summary['final_loss']:.4f} wall={summary['wall_s']:.1f}s "
          f"(floor {data.entropy:.4f})")


if __name__ == "__main__":
    main()
