"""Production mesh builders.

Functions, not module-level constants — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax init).  Mesh
construction goes through ``parallel.jaxcompat`` so both old and new jax
releases work.
"""
from __future__ import annotations

from repro.parallel.jaxcompat import make_mesh as _make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips ("data", "model").  Multi-pod: 2 pods =
    512 chips ("pod", "data", "model"); DP spans pod x data, MP stays
    intra-pod (DESIGN.md §5)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh(dp: int, mp: int, pods: int = 1):
    """Arbitrary hybrid mesh: the planner's (pod, N, M) factorization."""
    if pods > 1:
        return _make_mesh((pods, dp, mp), ("pod", "data", "model"))
    return _make_mesh((dp, mp), ("data", "model"))


def make_host_mesh():
    """1-device mesh for CPU tests."""
    return _make_mesh((1, 1), ("data", "model"))
