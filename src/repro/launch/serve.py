"""Serving launcher: load/initialize a model and decode batched requests.

Static batch (the classic path)::

    python -m repro.launch.serve --arch llama3_2_1b --reduced \
        --batch 4 --prompt-len 32 --max-new 16

Continuous batching over a slotted KV cache, optionally with the decode
tick on a dp x tp mesh (forced host devices work for CPU smoke runs)::

    python -m repro.launch.serve --arch llama3_2_1b --reduced \
        --continuous --slots 4 --tp 2 --prefill-chunk 8 \
        --batch 8 --prompt-len 32 --max-new 16

Multi-replica with fault injection (``serve.router.ReplicaRouter``:
least-loaded dispatch, health-checked failover, bounded queues)::

    python -m repro.launch.serve --arch llama3_2_1b --reduced \
        --continuous --replicas 2 --slots 4 --max-queue 16 \
        --fault "kill@5:0" --batch 8 --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.api import build_model
from repro.serve.continuous import ContinuousEngine, Request
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="requests (continuous) / batch rows (static)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--continuous", action="store_true",
                    help="slotted continuous-batching engine")
    ap.add_argument("--slots", type=int, default=4,
                    help="request slots (continuous engine)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="max prompt tokens per prefill step (0 = one shot)")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-MP ways for the decode tick (needs >= tp "
                    "devices; use XLA_FLAGS=--xla_force_host_platform_"
                    "device_count=N on CPU)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="independent continuous-engine replica groups "
                    "behind the fault-tolerant router (tp devices each)")
    ap.add_argument("--fault", default="",
                    help="replica-keyed fault schedule, e.g. "
                    "'kill@5:0, stall@7:1:0.5, nanlogits@9:0'")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="bound on queued requests per replica; overflow "
                    "is shed (0 = unbounded)")
    ap.add_argument("--watchdog", type=float, default=0.0,
                    help="router health watchdog seconds (0 = off). Leave "
                    "off on cold CPU runs: every distinct prefill-chunk "
                    "shape retraces for seconds and reads as a stall")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    api = build_model(cfg, remat=False)
    key = jax.random.PRNGKey(0)
    params = api.init(key)

    tokens = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size, dtype=jnp.int32)

    if args.continuous:
        capacity = args.prompt_len + args.max_new + 8
        reqs = [Request(rid=i, tokens=[int(t) for t in tokens[i]],
                        max_new_tokens=args.max_new)
                for i in range(args.batch)]
        if args.replicas > 1 or args.fault or args.max_queue:
            import numpy as np
            from repro.serve.router import ReplicaRouter
            from repro.train.fault import parse_fault_schedule
            meshes = model_axis = None
            batch_axes = ()
            if args.tp > 1:
                devs = jax.devices()
                need = args.replicas * args.tp
                if need > len(devs):
                    raise SystemExit(
                        f"--replicas {args.replicas} x --tp {args.tp} needs "
                        f"{need} devices, only {len(devs)} visible")
                meshes = [jax.sharding.Mesh(
                    np.asarray(devs[r * args.tp:(r + 1) * args.tp]
                               ).reshape(1, args.tp), ("data", "model"))
                    for r in range(args.replicas)]
                model_axis, batch_axes = "model", ("data",)
            router = ReplicaRouter(
                api, params, replicas=args.replicas, n_slots=args.slots,
                capacity=capacity, prefill_chunk=args.prefill_chunk,
                temperature=args.temperature, meshes=meshes,
                model_axis=model_axis, batch_axes=batch_axes,
                max_queue=args.max_queue or None,
                faults=parse_fault_schedule(args.fault) if args.fault else (),
                watchdog_timeout_s=args.watchdog or None, log_fn=print)
            t0 = time.time()
            results = router.run(reqs)
            dt = time.time() - t0
            router.close()
            toks = sum(len(r.tokens) for r in results)
            done = router.stats["completed"]
            print(f"[serve] router: {toks} tokens in {dt:.2f}s "
                  f"({toks / dt:.1f} tok/s, replicas={args.replicas}, "
                  f"tp={args.tp}, completed={done}, "
                  f"shed={router.stats['shed']}, "
                  f"timed_out={router.stats['timed_out']}, "
                  f"failovers={router.stats['failovers']}, "
                  f"states={router.replica_states})")
            print("first sequence:", results[0].tokens)
            return
        mesh = model_axis = None
        if args.tp > 1:
            from repro.parallel.jaxcompat import make_mesh
            n_dev = len(jax.devices())
            if n_dev % args.tp:
                raise SystemExit(f"--tp {args.tp} does not divide the "
                                 f"{n_dev} available devices")
            mesh = make_mesh((n_dev // args.tp, args.tp), ("data", "model"))
            model_axis = "model"
        engine = ContinuousEngine(
            api, params, n_slots=args.slots, capacity=capacity,
            prefill_chunk=args.prefill_chunk, temperature=args.temperature,
            mesh=mesh, model_axis=model_axis,
            batch_axes=("data",) if mesh is not None else ())
        t0 = time.time()
        results = engine.run(reqs)
        dt = time.time() - t0
        toks = sum(len(r.tokens) for r in results)
        print(f"[serve] continuous: {toks} tokens in {dt:.2f}s "
              f"({toks / dt:.1f} tok/s, slots={args.slots}, tp={args.tp})")
        print("first sequence:", results[0].tokens)
        return

    engine = ServeEngine(api, params, temperature=args.temperature)
    batch = {"tokens": tokens}
    if cfg.n_prefix_embeds:
        batch["prefix"] = jax.random.normal(
            key, (args.batch, min(cfg.n_prefix_embeds, 8), cfg.d_model)) * 0.02
    if cfg.encoder_layers:
        batch["frames"] = jax.random.normal(
            key, (args.batch, cfg.encoder_seq, cfg.d_model)) * 0.02

    t0 = time.time()
    res = engine.generate(batch, max_new_tokens=args.max_new, key=key)
    dt = time.time() - t0
    toks = args.batch * args.max_new
    print(f"[serve] {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s, batch={args.batch})")
    print("first sequence:", res.tokens[0].tolist())


if __name__ == "__main__":
    main()
