"""Serving launcher: load/initialize a model and decode batched requests.

    python -m repro.launch.serve --arch llama3_2_1b --reduced \
        --batch 4 --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.api import build_model
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    api = build_model(cfg, remat=False)
    key = jax.random.PRNGKey(0)
    params = api.init(key)
    engine = ServeEngine(api, params, temperature=args.temperature)

    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size, dtype=jnp.int32)}
    if cfg.n_prefix_embeds:
        batch["prefix"] = jax.random.normal(
            key, (args.batch, min(cfg.n_prefix_embeds, 8), cfg.d_model)) * 0.02
    if cfg.encoder_layers:
        batch["frames"] = jax.random.normal(
            key, (args.batch, cfg.encoder_seq, cfg.d_model)) * 0.02

    t0 = time.time()
    res = engine.generate(batch, max_new_tokens=args.max_new, key=key)
    dt = time.time() - t0
    toks = args.batch * args.max_new
    print(f"[serve] {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s, batch={args.batch})")
    print("first sequence:", res.tokens[0].tolist())


if __name__ == "__main__":
    main()
