import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input-shape x mesh) on the production meshes, prove memory
fit, and extract the roofline terms (deliverable g).

The two XLA_FLAGS lines above MUST stay first: jax locks the device count on
first init, and only the dry-run wants 512 placeholder host devices.

Per combo this produces:
  1. the REAL artifact — scan-over-layers, flash/chunked attention — whose
     ``.lower().compile()`` success is the dry-run pass and whose
     ``memory_analysis()`` proves fit;
  2. two ANALYSIS artifacts (1-layer and 2-layer configs, fully unrolled
     scans) whose cost_analysis/collective-parse delta gives exact per-layer
     FLOPs/bytes/collective traffic; totals = base + L * per-layer.  This
     sidesteps XLA's while-loop-body-counted-once limitation (DESIGN.md §5).

Usage:
  python -m repro.launch.dryrun --arch all --shape all --mesh both \
      --plan baseline --out results/dryrun
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.core import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.parallel.jaxcompat import cost_analysis, set_mesh
from repro.models.api import build_model, make_input_specs
from repro.optim import adafactor, adamw, constant_lr
from repro.parallel.plan import ParallelPlan
from repro.train.steps import (TrainState, _make_pctx, make_train_step,
                               shardings_for)

# archs whose optimizer state must be factored to fit HBM (DESIGN.md §4)
ADAFACTOR_ARCHS = {"kimi_k2_1t_a32b", "nemotron_4_340b"}


def make_plan(arch: str, mesh, plan_name: str, schedule: str = "gpipe",
              pipe_runtime: str = "scheduled",
              comm_runtime: str = "gspmd",
              comm_chunks: int = 1,
              context_parallel: bool = False) -> ParallelPlan:
    multi = "pod" in mesh.axis_names
    dp_axes = ("pod", "data") if multi else ("data",)
    fsdp = dp_axes if (plan_name == "optimized" or arch in ADAFACTOR_ARCHS) else ()
    # the giant archs need params sharded over DP to fit at all — that is the
    # ZeRO-3 "fsdp" addition; paper-faithful baseline for the rest keeps
    # params replicated across DP (sharded over model only)
    if plan_name == "pipeline":
        # model axis carries pipeline stages instead of tensor shards (§4.4);
        # ShardingRules switches to stage-dim rules so memory_analysis
        # reports per-stage parameter residency
        return ParallelPlan(dp_axes=dp_axes, model_axis="model",
                            mp_kind="pipeline", microbatches=4,
                            schedule=schedule,
                            virtual_stages=2 if schedule == "interleaved" else 1,
                            runtime=pipe_runtime,
                            fsdp_axes=tuple(fsdp))
    if context_parallel:
        # model axis carries the sequence-sharded KV ring (parallel.context):
        # params replicated across it, activations 1/16 per device — the
        # long-context training lane (train shapes only; decode keeps its
        # dense cache attention)
        return ParallelPlan(dp_axes=dp_axes, model_axis="model",
                            mp_kind="context", fsdp_axes=tuple(fsdp))
    return ParallelPlan(dp_axes=dp_axes, fsdp_axes=tuple(fsdp),
                        comm_runtime=comm_runtime, comm_chunks=comm_chunks)


def make_optimizer(arch: str):
    if arch in ADAFACTOR_ARCHS:
        return adafactor(constant_lr(1e-3))
    return adamw(constant_lr(1e-3))


def build_step(cfg, shape, mesh, plan, *, unroll: bool):
    """Returns (jitted_fn, example_args_specs) for this (cfg, shape).

    ``unroll`` marks an ANALYSIS artifact: every scan fully unrolls so the
    HLO cost analysis counts all iterations (layers.set_analysis_unroll —
    the flag is consumed lazily at trace time, i.e. inside .lower()).
    """
    from repro.models import layers as _layers
    _layers.set_analysis_unroll(unroll)
    if shape.kind != "train":
        # inference deployment: bf16 weights, no f32 master copies
        cfg = dataclasses.replace(cfg, param_dtype="bfloat16")
    api = build_model(cfg, remat=plan.remat)
    specs = make_input_specs(cfg, shape)
    opt = make_optimizer(cfg.name.replace("-", "_").replace(".", "_"))
    pctx = _make_pctx(mesh, plan,
                      batch_shardable=_batch_shardable(specs, mesh, plan),
                      decode=shape.kind == "decode")
    state_sh, batch_sh = shardings_for(api, mesh, plan, opt, specs)

    if shape.kind == "decode":
        from repro.train.steps import make_serve_steps
        _, decode_step = make_serve_steps(api, pctx=pctx)

        def fn(params, batch):
            return decode_step(params, batch)

        params_shape = jax.eval_shape(api.init, jax.random.PRNGKey(0))
        args = (params_shape, specs)
        in_sh = (state_sh.params, batch_sh)
        # pin the output cache to the input cache shardings so donation
        # aliases the buffers (otherwise memory_analysis double-counts the
        # cache — §Perf iteration B.4)
        from jax.sharding import NamedSharding, PartitionSpec as P
        logits_sh = NamedSharding(mesh, P(
            plan.dp_axes if _batch_shardable(specs, mesh, plan) else None,
            None, None))
        jitted = jax.jit(fn, in_shardings=in_sh,
                         out_shardings=(logits_sh, batch_sh["cache"]),
                         donate_argnums=(1,))
        return jitted, args

    if shape.kind == "prefill":
        def fn(params, batch):
            # capacity covers the full sequence incl. VLM prefix embeds
            logits, cache = api.prefill(params, batch, pctx,
                                        capacity=shape.seq_len)
            return logits, cache

        params_shape = jax.eval_shape(api.init, jax.random.PRNGKey(0))
        args = (params_shape, specs)
        jitted = jax.jit(fn, in_shardings=(state_sh.params, batch_sh))
        return jitted, args

    # train
    train_step = make_train_step(api, opt, mesh=mesh, plan=plan, pctx=pctx)
    params_shape = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    opt_shape = jax.eval_shape(opt.init, params_shape)
    state_shape = TrainState(params=params_shape, opt_state=opt_shape,
                             step=jax.ShapeDtypeStruct((), jnp.int32))
    args = (state_shape, specs)
    jitted = jax.jit(train_step, in_shardings=(state_sh, batch_sh),
                     donate_argnums=(0,))
    return jitted, args


def _batch_shardable(specs, mesh, plan) -> bool:
    # judge by the token batch dim only (cache leaves carry a stacked layer
    # dim in front and would falsely veto)
    b = specs["tokens"].shape[0] if "tokens" in specs else \
        min(v.shape[0] for v in jax.tree.leaves(specs) if v.shape)
    dp = 1
    for a in plan.dp_axes:
        dp *= mesh.shape[a]
    return b % dp == 0 and dp > 1


def _specs_seqlen(specs) -> int:
    return specs["tokens"].shape[1]


def _unrolled_variant(cfg, n_layers: int):
    kw = {"n_layers": n_layers}
    if cfg.encoder_layers:
        kw["encoder_layers"] = n_layers
    return dataclasses.replace(cfg, **kw)


def analyze_combo(arch: str, shape_name: str, *, multi_pod: bool,
                  plan_name: str = "baseline", skip_analysis: bool = False,
                  unroll_analysis: bool = True, schedule: str = "gpipe",
                  pipe_runtime: str = "scheduled",
                  comm_runtime: str = "gspmd", comm_chunks: int = 1,
                  context_parallel: bool = False):
    """Run the dry-run for one (arch, shape, mesh) and return the record."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    plan = make_plan(arch, mesh, plan_name, schedule=schedule,
                     pipe_runtime=pipe_runtime, comm_runtime=comm_runtime,
                     comm_chunks=comm_chunks, context_parallel=context_parallel)
    if comm_runtime != "gspmd":
        rec_comm = {"comm_runtime": comm_runtime, "comm_chunks": comm_chunks}
        print(f"  [comm] runtime={comm_runtime} chunks={comm_chunks}",
              flush=True)
    else:
        rec_comm = None
    if plan.is_pipeline:
        # the 1-/2-layer unroll artifacts cannot be partitioned into the
        # 16-stage pipeline; per-layer cost deltas are tensor-plan-only
        skip_analysis = True
    if plan.is_context:
        t_full = _specs_seqlen(make_input_specs(cfg, shape))
        print(f"  [ctx] 16-way kv ring, seq {t_full} -> "
              f"{t_full // 16} per device", flush=True)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
           "plan": plan_name + ("__cp" if plan.is_context else ""),
           "plan_detail": plan.describe(mesh)}
    if rec_comm:
        rec["comm"] = rec_comm
    if plan.is_pipeline:
        # the schedule's predicted idle fraction and activation residency
        # (keyed off the runtime that will execute it), printed next to the
        # lane banner and persisted with the record
        from repro.parallel.pipeline import (make_schedule,
                                             pipeline_activation_residency)
        stages = mesh.shape["model"]
        sched_obj = make_schedule(plan.schedule, stages, plan.microbatches,
                                  plan.virtual_stages)
        resid = pipeline_activation_residency(
            plan.microbatches, stages, plan.schedule, plan.virtual_stages,
            runtime=plan.runtime)
        rec["pipeline"] = {
            "schedule": plan.schedule, "runtime": plan.runtime,
            "n_stages": stages, "n_micro": plan.microbatches,
            "virtual_stages": sched_obj.v,
            "bubble_fraction": sched_obj.bubble_fraction(),
            "activation_residency_microbatches": resid,
        }
        print(f"  [pipe] {sched_obj.describe()} runtime={plan.runtime} "
              f"resid@runtime={resid:.1f}", flush=True)

    t0 = time.time()
    with set_mesh(mesh):
        jitted, args = build_step(cfg, shape, mesh, plan, unroll=False)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 1)
    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "alias_bytes": ma.alias_size_in_bytes,
        "peak_bytes": (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                       + ma.output_size_in_bytes - ma.alias_size_in_bytes),
        "hbm_per_chip": rl.HBM_PER_CHIP,
    }
    rec["fits"] = rec["memory"]["peak_bytes"] <= rl.HBM_PER_CHIP
    ca = cost_analysis(compiled)
    rec["real_cost"] = {"flops": ca.get("flops", 0.0),
                        "bytes": ca.get("bytes accessed", 0.0)}
    coll_real = rl.parse_collectives(compiled.as_text(), default_group=chips)
    rec["real_collectives"] = coll_real.to_dict()

    if not skip_analysis:
        # per-layer-exact analysis artifacts
        costs = {}
        for nl in (1, 2):
            cfg_n = _unrolled_variant(cfg, nl)
            with set_mesh(mesh):
                j, a = build_step(cfg_n, shape, mesh, plan, unroll=unroll_analysis)
                low = j.lower(*a)
                comp = low.compile()
            c = cost_analysis(comp)
            coll = rl.parse_collectives(comp.as_text(), default_group=chips)
            costs[nl] = {"flops": c.get("flops", 0.0),
                         "bytes": c.get("bytes accessed", 0.0),
                         "wire": coll.wire_bytes,
                         "ops": coll.ops}
        L = cfg.n_layers
        # clamp: XLA's collective-combiner can merge ops differently between
        # the 1L and 2L builds, occasionally making the delta slightly
        # negative — a per-layer cost is physically >= 0
        per_layer = {k: max(0.0, costs[2][k] - costs[1][k])
                     for k in ("flops", "bytes", "wire")}
        total = {k: costs[1][k] + (L - 1) * per_layer[k]
                 for k in ("flops", "bytes", "wire")}
        rec["analysis"] = {"one_layer": costs[1], "two_layer": costs[2],
                           "per_layer": per_layer, "total": total}
        flops_pc, bytes_pc, wire_pc = total["flops"], total["bytes"], total["wire"]
    else:
        flops_pc = rec["real_cost"]["flops"]
        bytes_pc = rec["real_cost"]["bytes"]
        wire_pc = coll_real.wire_bytes

    roof = rl.Roofline(
        chips=chips,
        hlo_flops_per_chip=flops_pc,
        hlo_bytes_per_chip=bytes_pc,
        collective_wire_bytes_per_chip=wire_pc,
        model_flops_total=rl.model_flops(cfg, shape),
        crosses_pod=multi_pod,
    )
    rec["roofline"] = roof.to_dict()
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--plan", default="baseline",
                    choices=["baseline", "optimized", "pipeline"])
    ap.add_argument("--sched", default=None,
                    choices=["gpipe", "1f1b", "interleaved"],
                    help="pipeline schedule for --plan pipeline "
                         "(default gpipe; interleaved implies v=2)")
    ap.add_argument("--pipe-runtime", default=None,
                    choices=["scheduled", "ad"],
                    help="pipeline runtime for --plan pipeline (default "
                         "scheduled: the hand-scheduled fwd+bwd executor)")
    ap.add_argument("--comm-runtime", default=None,
                    choices=["gspmd", "overlapped"],
                    help="collective runtime for the tensor-MP plans: "
                         "'overlapped' compiles the Megatron matmuls "
                         "through parallel.collectives' chunked ppermute "
                         "rings (train shapes); default gspmd")
    ap.add_argument("--comm-chunks", type=int, default=1,
                    help="ring chunks per shard for --comm-runtime "
                         "overlapped")
    ap.add_argument("--context-parallel", action="store_true",
                    help="swap the tensor shards for a 16-way KV ring "
                         "(mp_kind='context'): sequence sharded over the "
                         "model axis, weights replicated; train shapes "
                         "whose seq divides by 16 only")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-analysis", action="store_true")
    args = ap.parse_args()

    # validate the pipeline-only knobs early: silently ignoring --sched or
    # --pipe-runtime on a non-pipeline plan would dry-run a different
    # strategy than the operator asked for
    if args.plan != "pipeline":
        for flag, val in (("--sched", args.sched),
                          ("--pipe-runtime", args.pipe_runtime)):
            if val is not None:
                raise SystemExit(
                    f"[plan] {flag} {val} only applies to --plan pipeline "
                    f"(got --plan {args.plan}); drop the flag or select the "
                    f"pipeline plan")
    elif args.comm_runtime is not None or args.comm_chunks != 1:
        raise SystemExit(
            "[plan] --comm-runtime/--comm-chunks apply to the tensor-MP "
            "plans (baseline/optimized); pipeline stages exchange "
            "activations over their own ppermute rings (see --pipe-runtime)")
    if args.comm_chunks != 1 and args.comm_runtime != "overlapped":
        raise SystemExit("[plan] --comm-chunks only applies with "
                         "--comm-runtime overlapped")
    if args.context_parallel:
        # context is its own model-axis scheme: it replaces the tensor
        # shards and already schedules its own KV ring (plan.__post_init__
        # rejects the overlapped-collectives combination too)
        if args.plan == "pipeline":
            raise SystemExit("[plan] --context-parallel replaces the model "
                             "axis' tensor shards; it cannot combine with "
                             "--plan pipeline")
        if args.comm_runtime is not None or args.comm_chunks != 1:
            raise SystemExit("[plan] --comm-runtime/--comm-chunks apply to "
                             "the tensor-MP plans; the context plan's KV "
                             "ring schedules its own ppermute collectives")
    sched = args.sched or "gpipe"
    pipe_runtime = args.pipe_runtime or "scheduled"
    comm_runtime = args.comm_runtime or "gspmd"

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(INPUT_SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    n_ok = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                if args.plan == "pipeline":
                    # pipeline plans: train-mode only, and the 16-way model
                    # axis (x v chunks for interleaved) must evenly
                    # partition the arch's layer stack
                    from repro.models.api import pipeline_applicable
                    v = 2 if sched == "interleaved" else 1
                    if (INPUT_SHAPES[shape].kind != "train"
                            or not pipeline_applicable(get_config(arch), 16, v)):
                        print(f"[skip] {arch}__{shape} (pipeline n/a)")
                        continue
                if args.context_parallel:
                    # the KV ring shards the sequence 16 ways and only
                    # engages on the train path (decode shapes keep their
                    # dense cache attention)
                    sh = INPUT_SHAPES[shape]
                    cfg_a = get_config(arch)
                    seq = make_input_specs(cfg_a, sh)["tokens"].shape[1]
                    if sh.kind != "train" or seq % 16:
                        print(f"[skip] {arch}__{shape} (context n/a: "
                              f"kind={sh.kind} seq={seq})")
                        continue
                tag = f"{arch}__{shape}__{'multi' if multi else 'single'}__{args.plan}"
                if args.context_parallel:
                    tag += "__cp"
                if comm_runtime != "gspmd":
                    tag += f"__{comm_runtime}"
                out_path = os.path.join(args.out, tag + ".json")
                if os.path.exists(out_path):
                    print(f"[skip] {tag} (cached)")
                    n_ok += 1
                    continue
                print(f"[run ] {tag}", flush=True)
                try:
                    # analysis artifacts only needed on the single-pod mesh
                    rec = analyze_combo(arch, shape, multi_pod=multi,
                                        plan_name=args.plan,
                                        skip_analysis=args.skip_analysis or multi,
                                        schedule=sched,
                                        pipe_runtime=pipe_runtime,
                                        comm_runtime=comm_runtime,
                                        comm_chunks=args.comm_chunks,
                                        context_parallel=args.context_parallel)
                    with open(out_path, "w") as f:
                        json.dump(rec, f, indent=1)
                    r = rec["roofline"]
                    print(f"  ok {rec['compile_s']}s fit={rec['fits']} "
                          f"bottleneck={r['bottleneck']} "
                          f"t=({r['t_compute']:.3e},{r['t_memory']:.3e},"
                          f"{r['t_collective']:.3e})s mfu={r['mfu']:.2f}",
                          flush=True)
                    n_ok += 1
                except Exception as e:
                    n_fail += 1
                    print(f"  FAIL {type(e).__name__}: {e}", flush=True)
                    with open(out_path + ".err", "w") as f:
                        f.write(traceback.format_exc())
    print(f"dry-run complete: {n_ok} ok, {n_fail} failed")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
