"""DLPlacer (paper §6): operation-to-device placement for model parallelism.

Faithful encoding of the paper's ILP — placement variables P_kn (Eq. 7),
activation routing C_el (Eqs. 8-9), dependency + communication scheduling
(Eqs. 10-11), device serialization (Eq. 12), and memory capacity (Eq. 13) —
solved with exact branch-and-bound over placements (the offline container has
no ILP solver; B&B with critical-path/workload lower bounds gives the same
optimal solutions with a certificate, for the DFG sizes the paper uses).
Routing on the all-to-all NVLink topology of the paper's DGX-1 collapses to
the direct link, so Eqs. 8-9 reduce to a per-edge delay D(e)/B(l) + L(l); for
multi-hop topologies the schedule uses shortest-path link chains.

The *simulated executor* replays a placement with per-op launch overheads and
imperfect comm/compute overlap — the stand-in for the paper's "silicon"
measurements in the Fig. 8 validation benchmark.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import time
from typing import Dict, List, Optional, Sequence, Tuple

import networkx as nx


@dataclasses.dataclass(frozen=True)
class OpCost:
    flops: float
    bytes_out: float
    mem: float = 0.0


@dataclasses.dataclass
class DFG:
    nodes: Dict[str, OpCost]
    edges: List[Tuple[str, str]]

    def graph(self) -> "nx.DiGraph":
        g = nx.DiGraph()
        for n, c in self.nodes.items():
            g.add_node(n, cost=c)
        g.add_edges_from(self.edges)
        assert nx.is_directed_acyclic_graph(g)
        return g

    @classmethod
    def from_analytic(cls, nodes: Dict[str, dict], edges) -> "DFG":
        return cls({n: OpCost(v["flops"], v["bytes_out"], v.get("mem", 0.0))
                    for n, v in nodes.items()}, list(edges))


@dataclasses.dataclass(frozen=True)
class HardwareGraph:
    """n_devices compute nodes; bw/lat matrices (direct links; all-to-all for
    NVLink-class systems, ring for ICI)."""

    n_devices: int
    flops_per_s: float = 15.7e12 * 0.4     # V100 fp32 w/ achievable fraction
    bw: float = 150e9                      # NVLink per direction
    latency: float = 5e-6
    mem_capacity: float = 16e9

    def comm_time(self, bytes_: float, src: int, dst: int) -> float:
        if src == dst:
            return 0.0
        return bytes_ / self.bw + self.latency


def exec_time(cost: OpCost, hw: HardwareGraph) -> float:
    return cost.flops / hw.flops_per_s


def list_schedule(dfg: DFG, hw: HardwareGraph,
                  placement: Dict[str, int], *, op_overhead: float = 0.0,
                  comm_overlap: bool = True) -> float:
    """Makespan of a placement under the paper's scheduling constraints
    (Eqs. 10-12): deps + comm delays + per-device serialization.

    ``comm_overlap=True`` is DLPlacer assumption 2 (transfers hidden behind
    compute); False serializes transfers onto the source device — one of the
    'framework-induced overheads' knobs of the simulated executor.
    """
    g = dfg.graph()
    ready_t: Dict[str, float] = {}
    dev_free = [0.0] * hw.n_devices
    finish: Dict[str, float] = {}
    for n in nx.topological_sort(g):
        dev = placement[n]
        t_ready = 0.0
        for pred in g.predecessors(n):
            c = hw.comm_time(dfg.nodes[pred].bytes_out, placement[pred], dev)
            t_ready = max(t_ready, finish[pred] + c)
            if not comm_overlap and placement[pred] != dev:
                # transfer occupies the source device after the op finishes
                dev_free[placement[pred]] = max(dev_free[placement[pred]],
                                                finish[pred] + c)
        start = max(t_ready, dev_free[dev])
        finish[n] = start + exec_time(dfg.nodes[n], hw) + op_overhead
        dev_free[dev] = finish[n]
    return max(finish.values())


def memory_ok(dfg: DFG, hw: HardwareGraph, placement: Dict[str, int]) -> bool:
    use = [0.0] * hw.n_devices
    for n, c in dfg.nodes.items():
        use[placement[n]] += c.mem
    return all(u <= hw.mem_capacity for u in use)


@dataclasses.dataclass
class PlacementResult:
    placement: Dict[str, int]
    makespan: float
    lower_bound: float
    explored: int
    optimal: bool
    solve_s: float

    @property
    def speedup_vs_single(self) -> float:
        return self.single_device_time / self.makespan if self.makespan else 0.0

    single_device_time: float = 0.0


def _critical_path_lb(dfg: DFG, hw: HardwareGraph) -> float:
    g = dfg.graph()
    lb = {}
    for n in reversed(list(nx.topological_sort(g))):
        succ = [lb[s] for s in g.successors(n)]
        lb[n] = exec_time(dfg.nodes[n], hw) + (max(succ) if succ else 0.0)
    return max(lb.values())


def solve_placement(dfg: DFG, hw: HardwareGraph, *, time_budget_s: float = 60.0,
                    op_overhead: float = 0.0) -> PlacementResult:
    """Exact B&B over placements in topological order.

    Bounds: (a) work-balance LB = remaining-flops / (devices * rate) combined
    with committed device loads; (b) critical-path LB.  Symmetry broken by
    pinning the first node to device 0.  Falls back to best-found (with the
    proven bound) if the time budget expires — `optimal` records which.
    """
    g = dfg.graph()
    topo = list(nx.topological_sort(g))
    n_dev = hw.n_devices
    t_single = sum(exec_time(c, hw) for c in dfg.nodes.values()) \
        + op_overhead * len(dfg.nodes)
    cp_lb = _critical_path_lb(dfg, hw)

    # greedy warm start: HEFT-ish earliest-finish-time assignment
    best_place: Dict[str, int] = {}
    for n in topo:
        cands = []
        for d in range(n_dev):
            trial = dict(best_place, **{n: d})
            # complete greedily is expensive; assign by local EFT estimate
            cands.append((local_eft(dfg, hw, g, trial, n, d), d))
        best_place[n] = min(cands)[1]
    best_cost = list_schedule(dfg, hw, best_place, op_overhead=op_overhead)

    t0 = time.time()
    explored = 0
    suffix_work = {}
    acc = 0.0
    for n in reversed(topo):
        acc += exec_time(dfg.nodes[n], hw)
        suffix_work[n] = acc

    optimal = True

    def bnb(idx: int, placement: Dict[str, int], loads: List[float]):
        nonlocal best_cost, best_place, explored, optimal
        if time.time() - t0 > time_budget_s:
            optimal = False
            return
        explored += 1
        if idx == len(topo):
            cost = list_schedule(dfg, hw, placement, op_overhead=op_overhead)
            if cost < best_cost and memory_ok(dfg, hw, placement):
                best_cost, best_place = cost, dict(placement)
            return
        n = topo[idx]
        # lower bound: committed max load + perfectly parallel remaining work
        remaining = suffix_work[n]
        lb = max(max(loads), (sum(loads) + remaining) / n_dev, cp_lb)
        if lb >= best_cost:
            return
        devices = range(1 if idx == 0 else n_dev)  # symmetry breaking
        for d in devices:
            placement[n] = d
            loads[d] += exec_time(dfg.nodes[n], hw)
            bnb(idx + 1, placement, loads)
            loads[d] -= exec_time(dfg.nodes[n], hw)
        del placement[n]

    bnb(0, {}, [0.0] * n_dev)
    return PlacementResult(placement=best_place, makespan=best_cost,
                           lower_bound=max(cp_lb, t_single / n_dev),
                           explored=explored, optimal=optimal,
                           solve_s=time.time() - t0,
                           single_device_time=t_single)


def local_eft(dfg, hw, g, partial: Dict[str, int], node: str, dev: int) -> float:
    """Earliest finish time of `node` on `dev` given committed predecessors."""
    finish: Dict[str, float] = {}
    dev_free = [0.0] * hw.n_devices
    for n in nx.topological_sort(g):
        if n not in partial:
            break
        d = partial[n]
        t_ready = max((finish[p] + hw.comm_time(dfg.nodes[p].bytes_out,
                                                partial[p], d)
                       for p in g.predecessors(n) if p in finish), default=0.0)
        start = max(t_ready, dev_free[d])
        finish[n] = start + exec_time(dfg.nodes[n], hw)
        dev_free[d] = finish[n]
    return finish.get(node, 0.0)


def simulated_silicon(dfg: DFG, hw: HardwareGraph, placement: Dict[str, int],
                      *, op_overhead: float = 30e-6,
                      comm_overlap: bool = False) -> float:
    """The Fig. 8 'silicon' stand-in: same schedule with framework-style
    overheads (kernel launch cost, unoverlapped transfers)."""
    return list_schedule(dfg, hw, placement, op_overhead=op_overhead,
                         comm_overlap=comm_overlap)
