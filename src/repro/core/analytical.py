"""The paper's analytical framework (§3, Eqs. 1-6), as executable code.

    C = T x S x E                                   (Eq. 1)
    SU_N = SE_N * N * E_1/E_N                       (Eq. 3, N-way DP)
    SU_{M*N} = SE_{M*N} * M * N * E_1/E_{M*N}       (Eq. 4, DP-only at M*N)
    SU_N^M = SU^M * SE_N * N * E_1/E_N              (Eq. 5, hybrid)
    hybrid wins iff  SU^M > M * SE_{M*N}/SE_N * E_N/E_{M*N}   (Eq. 6)

``TrainingRun`` carries the per-network inputs (step time on one device, grad
bytes, epoch model, mini-batch size); the functions below evaluate the
speedup curves the paper plots in Fig. 3/5 and the crossover criterion.

The per-step MP speedup SU^M comes in two flavors, mirroring the paper's two
MP implementations (§4.3/§4.4):

- **tensor** MP (``mp_speedup``: M -> SU^M) — intra-layer sharding, the
  Megatron/DLPlacer style the paper measures for Inception-V3;
- **pipeline** MP (``pipe_speedup``: (M, K, schedule) -> SU^M for M stages,
  K micro-batches and a pipeline schedule) — layer pipelining, the style
  the paper uses for GNMT and BigLSTM, with SU^M = M * (1 - bubble) /
  (1 + comm), where bubble is the schedule's idle fraction
  ((M-1)/(K+M-1) for gpipe/1f1b, (M-1)/(vK+M-1) for interleaved — see
  ``parallel.pipeline``) and comm is the inter-stage activation-transfer
  time as a fraction of per-micro-batch stage compute.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

from repro.core.comm import HardwareModel, scaling_efficiency
from repro.core.stateff import EpochModel


@dataclasses.dataclass(frozen=True)
class TrainingRun:
    """Inputs of the analytical model for one network on one system."""

    name: str
    t1: float                      # time per step on a single device (s)
    grad_bytes: float              # gradient exchange size (bytes)
    mini_batch: int                # per-worker batch (constant, paper §3.1)
    epoch_model: EpochModel
    dataset_size: int              # items per epoch
    mp_speedup: Dict[int, float]   # M -> tensor-MP SU^M (Table 1 / DLPlacer)
    hw: HardwareModel = HardwareModel()
    se_perfect: bool = True        # paper's conservative SE_N = 1
    # (M stages, K micro-batches, schedule) -> pipeline-MP SU^M (per-schedule
    # bubble model); plain (M, K) keys are accepted as gpipe for back-compat
    pipe_speedup: Dict[Tuple, float] = \
        dataclasses.field(default_factory=dict)
    # M -> context-parallel SU^M (sequence-sharded KV ring, planner's
    # cp_step_speedup; empty when the arch has no CP path)
    cp_speedup: Dict[int, float] = dataclasses.field(default_factory=dict)
    # Measured fraction of the DP gradient exchange hidden under backward
    # compute (comm.MEASURED_OVERLAP keyed by the selected comm runtime: 0
    # for GSPMD's monolithic all-reduce) and the runtime's bucket size (> 0
    # charges the bucketed sync's per-bucket alpha cost).
    comm_overlap: float = 0.0
    bucket_bytes: float = 0.0


def se(run: TrainingRun, n: int, *, overlap: Optional[float] = None,
       grad_scale: float = 1.0, hybrid: bool = False) -> float:
    """Scaling efficiency SE_N of N-way DP.  ``grad_scale`` shrinks the
    gradient exchange for hybrid points (each M-way-MP worker owns — and
    all-reduces — only 1/M of the parameters).  ``overlap`` defaults to the
    run's measured comm overlap (keyed off the selected comm runtime) —
    EXCEPT for ``hybrid`` points: the bucketed/overlapped DP grad sync only
    executes for pure-DP plans (train.steps gates it on model-axis size 1),
    so MP workers' exchanges are costed as the fused exposed all-reduce.
    The planner must never credit a speedup the runtime cannot deliver."""
    if overlap is None:
        overlap = 0.0 if hybrid else run.comm_overlap
    bucket = 0.0 if hybrid else run.bucket_bytes
    return scaling_efficiency(run.grad_bytes * grad_scale, run.t1, n, run.hw,
                              overlap=overlap, bucket_bytes=bucket,
                              assume_perfect=run.se_perfect)


def epochs_ratio(run: TrainingRun, n_workers: int) -> float:
    """E_1 / E_N where N workers give global batch N * mini_batch."""
    e1 = run.epoch_model.epochs(run.mini_batch)
    en = run.epoch_model.epochs(n_workers * run.mini_batch)
    if en == float("inf"):
        return 0.0
    return e1 / en


def speedup_dp(run: TrainingRun, n: int) -> float:
    """Eq. 3: SU_N of N-way DP over a single device."""
    return se(run, n) * n * epochs_ratio(run, n)


def speedup_hybrid(run: TrainingRun, n_workers: int, m: int) -> float:
    """Eq. 5: N-way DP of M-way-MP workers, M*N devices total."""
    su_m = run.mp_speedup.get(m, 0.0) if m > 1 else 1.0
    return (su_m * se(run, n_workers, grad_scale=1.0 / max(m, 1),
                      hybrid=m > 1)
            * n_workers * epochs_ratio(run, n_workers))


def speedup_context(run: TrainingRun, n_workers: int, m: int) -> float:
    """Eq. 5 with context-parallel workers: N-way DP of M-device KV rings,
    M*N devices total.  CP REPLICATES the parameters across the ring, so —
    unlike tensor-MP's 1/M grad discount — every one of the M*N devices
    all-reduces the FULL gradient (the ring members see different tokens of
    the same sequences, so their grads must sum): SE is evaluated at M*N
    workers with grad_scale=1.  CP buys its per-step 1/M at full sync cost,
    which is exactly why the planner only picks it when the sequence axis
    is what blows the memory budget."""
    if m <= 1:
        return speedup_dp(run, n_workers)
    su_m = run.cp_speedup.get(m, 0.0)
    return (su_m * se(run, n_workers * m, grad_scale=1.0, hybrid=True)
            * n_workers * epochs_ratio(run, n_workers))


def speedup_pipeline(run: TrainingRun, n_workers: int, m: int,
                     n_micro: int, schedule: str = "gpipe") -> float:
    """Eq. 5 with pipeline-MP workers: N-way DP of M-stage pipelines fed with
    ``n_micro`` micro-batches each under ``schedule``, M*N devices total."""
    if m <= 1:
        return speedup_dp(run, n_workers)
    su_m = run.pipe_speedup.get((m, n_micro, schedule),
                                run.pipe_speedup.get((m, n_micro), 0.0)
                                if schedule == "gpipe" else 0.0)
    return (su_m * se(run, n_workers, grad_scale=1.0 / m, hybrid=True)
            * n_workers * epochs_ratio(run, n_workers))


def hybrid_wins(run: TrainingRun, n: int, m: int) -> bool:
    """Eq. 6 at M*N total devices: is N-way DP x M-way MP better than
    (M*N)-way DP?"""
    return speedup_hybrid(run, n, m) > speedup_dp(run, m * n)


def crossover_device_count(run: TrainingRun, m: int = 2,
                           max_devices: int = 4096) -> Optional[int]:
    """Smallest total device count D (power of 2) where the hybrid strategy
    (D/m-way DP x m-way MP) beats DP-only at D devices — the paper's 'tipping
    point'."""
    d = m
    while d <= max_devices:
        if hybrid_wins(run, d // m, m):
            return d
        d *= 2
    return None


def best_strategy(run: TrainingRun, total_devices: int) -> Dict:
    """Arg-max over all factorizations total = N * M (M in mp_speedup U {1}):
    the paper's §3.4 choice, generalized to every available M."""
    best = {"m": 1, "n": total_devices,
            "speedup": speedup_dp(run, total_devices)}
    for m, su in sorted(run.mp_speedup.items()):
        if total_devices % m:
            continue
        n = total_devices // m
        s = speedup_hybrid(run, n, m)
        if s > best["speedup"]:
            best = {"m": m, "n": n, "speedup": s}
    best["convergence_time"] = convergence_time(run, best["n"], best["m"])
    return best


def convergence_time(run: TrainingRun, n_workers: int, m: int = 1) -> float:
    """Eq. 1 evaluated for a hybrid configuration, in seconds."""
    su_m = run.mp_speedup.get(m, 1.0) if m > 1 else 1.0
    t = run.t1 / (se(run, n_workers, grad_scale=1.0 / max(m, 1),
                     hybrid=m > 1) * su_m)
    global_batch = n_workers * run.mini_batch
    s = run.dataset_size / global_batch
    e = run.epoch_model.epochs(global_batch)
    return t * s * e
