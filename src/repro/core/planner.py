"""HybridPlanner — the paper's strategy search as a first-class feature.

Given an architecture config, a device budget, and hardware constants, the
planner runs a unified **3-way search** over every factorization of the
budget into

    total = pods x N (data parallel) x M (model parallel),

where the M-way model parallelism is either **tensor-MP** (intra-layer
sharding on the ICI torus, the paper's §4.3 / DLPlacer style) or
**pipeline-MP** (layer pipelining with K micro-batches under a searched
**schedule** — gpipe / 1f1b / interleaved, see ``parallel.pipeline`` — the
paper's §4.4 implementation for GNMT and BigLSTM).  For each point it

(a) builds a per-step cost model from the arch's FLOPs/bytes:
    tensor SU^M from the Megatron all-reduce pattern, pipeline SU^M from the
    schedule's analytic bubble fraction ((M-1)/(K+M-1) for gpipe/1f1b,
    (M-1)/(vK+M-1) for interleaved) plus the inter-stage ``ppermute``
    activation-transfer time (scaled by v for interleaved's extra rings);
(b) derives SE_N from the (hierarchical) ring-all-reduce model, with the
    gradient exchange scaled by 1/M because each MP worker owns 1/M of the
    parameters;
(c) takes E(B) from measured curves or the fitted inflation model;
(d) applies a per-device **memory-feasibility filter** — f32 master params +
    optimizer state + gradients + remat boundary activations, ZeRO/fsdp-aware
    and **schedule-aware** (gpipe holds all K micro-batch activations, 1f1b
    at most min(K, S) — so 1f1b keeps micro-batch counts feasible that gpipe
    cannot fit), keyed off the **pipeline runtime** that will execute the
    plan (``pipe_runtime="scheduled"`` realizes the schedule's residency
    bound via ``pipeline_value_and_grad``; ``"ad"`` holds all K for every
    schedule, so 1f1b's memory edge vanishes there): a point that only fits
    with params/opt sharded over DP is emitted with ``fsdp_axes`` set, and a
    point that does not fit even then is pruned rather than ranked;
(e) evaluates Eq. 4 vs Eq. 5 over the surviving points and returns them
    best-first, each as an executable ``ParallelPlan`` (tensor plans with
    ``model_axis``, pipeline plans additionally with ``mp_kind="pipeline"``,
    ``microbatches=K``, ``schedule``, ``virtual_stages``) + mesh shape.

``launch/train.py --parallel auto`` calls this and actually runs the winning
plan (pipeline plans go through ``parallel.pipeline.pipeline_apply``);
explicit ``--parallel dp=16,mp=16`` / ``--parallel pipe=4,micro=8`` overrides.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.core.analytical import (TrainingRun, speedup_context, speedup_dp,
                                   speedup_hybrid, speedup_pipeline)
from repro.core.comm import (HardwareModel, cp_ring_time,
                             hierarchical_all_reduce_time, p2p_transfer_time)
from repro.core.stateff import EpochModel, fit_epoch_model
from repro.parallel.pipeline import (pipeline_activation_residency,
                                     pipeline_step_speedup)
from repro.parallel.plan import ParallelPlan, serve_plan

# interleaved virtual chunks per device the planner searches (Megatron's v)
INTERLEAVE_CHUNKS = 2


@dataclasses.dataclass(frozen=True)
class PlannerChoice:
    pods: int
    dp: int                        # per-pod DP degree (N = pods * dp)
    mp: int
    mp_kind: str                   # "none" | "tensor" | "pipeline" | "context"
    microbatches: int              # pipeline micro-batches K (1 otherwise)
    schedule: str                  # pipeline schedule ("-" for non-pipeline)
    virtual_stages: int            # interleaved chunks per device (v)
    speedup: float                 # projected SU over a single device (Eq. 5)
    su_m: float                    # per-step MP speedup used
    se_n: float
    epochs_ratio: float
    mem_bytes: float               # projected per-device working set
    mesh_shape: Tuple[int, ...]
    plan: ParallelPlan

    @property
    def n_workers(self) -> int:
        return self.pods * self.dp


@dataclasses.dataclass(frozen=True)
class InferenceChoice:
    """One point of the latency-SLO-constrained serving search: ``replicas``
    independent decode groups of ``tp`` chips, each running a continuous-
    batching engine with ``slots`` request lanes."""
    replicas: int
    tp: int
    slots: int                     # concurrent requests per replica
    step_latency: float            # modeled s/token for the full batch
    tokens_per_s: float            # sustained: replicas * slots / step
    mem_bytes: float               # per-chip weights + KV working set
    mesh_shape: Tuple[int, ...]    # (replicas, tp) decode mesh per group
    plan: ParallelPlan

    @property
    def n_devices(self) -> int:
        return self.replicas * self.tp

    def build_router(self, api, params, *, capacity: int, **kw):
        """Execute this choice rather than just reporting it: instantiate
        the ``replicas`` x ``tp`` engine groups with ``slots`` lanes each
        behind a fault-tolerant ``serve.router.ReplicaRouter`` (least-loaded
        dispatch, health checks, mid-flight failover).  ``capacity`` is the
        per-slot KV budget in positions; ``kw`` forwards to the router
        (faults, watchdog, max_queue, ...).  Lazy import: ``core`` stays
        importable without the serving stack."""
        from repro.serve.router import ReplicaRouter
        return ReplicaRouter.from_choice(api, params, self,
                                         capacity=capacity, **kw)


def kv_bytes(cfg: ModelConfig, slots: int, context: int) -> float:
    """bf16 KV cache bytes for ``slots`` requests of ``context`` positions."""
    return (2.0 * cfg.n_layers * slots * context
            * cfg.n_kv_heads * cfg.head_dim * 2.0)


def decode_step_time(cfg: ModelConfig, tp: int, hw: HardwareModel, *,
                     slots: int, context: int,
                     comm_runtime: str = "gspmd") -> float:
    """Modeled latency of ONE decode tick (all ``slots`` advance a token) on
    a ``tp``-way tensor-MP group.

    Decode is bandwidth-bound: every tick streams this chip's 1/tp of the
    bf16 weights plus its share of the KV cache from HBM; the matmul FLOPs
    (2 * params * slots / tp) only bind at large batch.  On top rides the
    Megatron exchange — 2 activation all-reduces per layer of the (slots, d)
    residual — on the same ring model as training
    (``core.comm.ring_all_reduce_time``), with ``MEASURED_OVERLAP`` of the
    wire time hidden when the overlapped collective rings carry the step
    (the per-hop alpha latency is what dominates at decode sizes, which is
    exactly why the SLO search favors modest tp)."""
    from repro.core.comm import MEASURED_OVERLAP, ring_all_reduce_time
    p = float(cfg.n_active_params())
    t_mem = (2.0 * p / tp + kv_bytes(cfg, slots, context) / tp) / hw.hbm_bw
    t_flops = 2.0 * p * slots / (tp * hw.peak_flops * hw.mfu)
    t_comm = 0.0
    if tp > 1:
        act_bytes = slots * cfg.d_model * 2.0
        t_comm = (2.0 * cfg.n_layers
                  * ring_all_reduce_time(act_bytes, tp, hw.ici_bw,
                                         hw.ici_latency)
                  * (1.0 - MEASURED_OVERLAP[comm_runtime]))
    return max(t_mem, t_flops) + t_comm


def mp_step_speedup(cfg: ModelConfig, m: int, hw: HardwareModel,
                    comm_runtime: str = "gspmd") -> float:
    """Tensor-MP SU^M on the ICI torus: compute scales 1/m, plus the
    per-layer all-reduce of the (b, s, d) activations (2 per layer fwd, 2 bwd,
    Megatron pattern), with the ring's per-hop latency (alpha) term.  Uses
    bytes/FLOP analytics per arch family — the TPU analogue of the paper's
    measured Table 1 / DLPlacer estimates.  ``comm_runtime="overlapped"``
    hides the measured fraction of the transfer under the chunked
    collective-matmul's partial matmuls (comm.MEASURED_OVERLAP, calibrated
    by benchmarks/collective_overlap_sweep.py)."""
    if m <= 1:
        return 1.0
    from repro.core.comm import MEASURED_OVERLAP, ring_all_reduce_time
    # reference per-device micro-batch: 16 sequences of 4k tokens
    b, s = 16, 4096
    tokens = b * s
    flops = 6.0 * cfg.n_active_params() / cfg.n_layers * tokens  # per layer
    t_layer = flops / (hw.peak_flops * hw.mfu)
    act_bytes = tokens * cfg.d_model * 2
    n_ar = 4  # 2 fwd + 2 bwd all-reduces per layer (attn + mlp row-parallel)
    t_ar = n_ar * ring_all_reduce_time(act_bytes, m, hw.ici_bw,
                                       hw.ici_latency)
    t_ar *= 1.0 - MEASURED_OVERLAP[comm_runtime]
    return (t_layer) / (t_layer / m + t_ar)


def pipeline_step_speedup_model(cfg: ModelConfig, m: int, n_micro: int,
                                hw: HardwareModel, *, mini_batch: int,
                                seq_len: int, schedule: str = "gpipe",
                                virtual_stages: int = 1) -> float:
    """Pipeline-MP SU^M for an m-stage schedule with ``n_micro``
    micro-batches: the schedule's bubble fraction ((m-1)/(n_micro+m-1) for
    gpipe/1f1b, (m-1)/(v*n_micro+m-1) for interleaved) plus the inter-stage
    ``ppermute`` activation transfer (one (b/K, s, d) tensor forward and its
    gradient backward per boundary per micro-batch; interleaved rings the
    activations v times, so its transfer scales by v)."""
    if m <= 1:
        return 1.0
    v = max(virtual_stages, 1) if schedule == "interleaved" else 1
    tokens = mini_batch * seq_len
    t_step = 6.0 * cfg.n_active_params() * tokens / (hw.peak_flops * hw.mfu)
    t_stage_micro = t_step / (m * n_micro)
    act_bytes = tokens / n_micro * cfg.d_model * 2   # bf16 boundary activation
    t_xfer = 2.0 * v * p2p_transfer_time(act_bytes, hw)  # fwd act + bwd grad
    comm_fraction = t_xfer / max(t_stage_micro, 1e-30)
    return pipeline_step_speedup(m, n_micro, comm_fraction,
                                 schedule=schedule, virtual_stages=v)


def cp_step_speedup(cfg: ModelConfig, m: int, hw: HardwareModel, *,
                    mini_batch: int = 16, seq_len: int = 4096) -> float:
    """Context-parallel SU^M on the ppermute KV ring
    (``parallel.context.ring_attention``): ALL per-token compute scales 1/m
    — the residual stream is sequence-sharded end to end, so the matmuls
    split like the tokens do — and on top rides the per-layer ring cost
    (``core.comm.cp_ring_time``): (m-1) neighbor hops each carrying one
    sequence shard's bf16 K+V block, forward KV rotation plus the
    backward's KV + dK/dV rings.  GQA keeps the wire narrow: hop bytes
    scale with n_kv_heads, not n_heads, which is why CP's ring is so much
    cheaper than all-gathering KV."""
    if m <= 1:
        return 1.0
    tokens = mini_batch * seq_len
    flops = 6.0 * cfg.n_active_params() / cfg.n_layers * tokens  # per layer
    t_layer = flops / (hw.peak_flops * hw.mfu)
    # one shard's K + V block in bf16: (b, s/m, n_kv_heads, head_dim) x 2
    hop_bytes = 2.0 * mini_batch * (seq_len / m) * cfg.n_kv_heads \
        * cfg.head_dim * 2.0
    t_ring = cp_ring_time(hop_bytes, m, hw)
    return t_layer / (t_layer / m + t_ring)


def context_mp_supported(cfg: ModelConfig) -> bool:
    """Does the KV-ring context-parallel runtime execute this arch?  The
    SAME homogeneous-dense-decoder predicate the runtime gates on
    (``models.transformer.cp_supported``): the overlapped-arch family minus
    logit softcap (the ring's online-softmax merge has no softcap path)."""
    from repro.models.transformer import overlapped_arch_supported
    return (overlapped_arch_supported(cfg)
            and not getattr(cfg, "attn_logit_softcap", 0.0)
            and cfg.n_heads > 0)


def pipeline_stage_candidates(cfg: ModelConfig,
                              mp_candidates: Tuple[int, ...]) -> Tuple[int, ...]:
    """Stage counts that evenly partition the arch's layer stack(s)."""
    ok = []
    for m in mp_candidates:
        if m <= 1 or m > cfg.n_layers or cfg.n_layers % m:
            continue
        if cfg.encoder_layers and cfg.encoder_layers % m:
            continue
        ok.append(m)
    return tuple(ok)


def pipeline_schedule_candidates(cfg: ModelConfig, m: int,
                                 n_micro: int) -> Tuple[Tuple[str, int], ...]:
    """(schedule, v) points searchable at m stages with n_micro micros.

    gpipe and 1f1b partition any stack m already divides; interleaved
    additionally needs v chunks per device (layers % (m*v) == 0) and the
    packed Megatron wave (m | n_micro) for its (m-1)/(v*K+m-1) bubble."""
    out = [("gpipe", 1), ("1f1b", 1)]
    v = INTERLEAVE_CHUNKS
    if (n_micro % m == 0 and cfg.n_layers % (m * v) == 0
            and (not cfg.encoder_layers or cfg.encoder_layers % (m * v) == 0)):
        out.append(("interleaved", v))
    return tuple(out)


def tensor_mp_supported(cfg: ModelConfig) -> bool:
    """The paper implements MP for the RNN models (GNMT, BigLSTM) as
    pipeline parallelism only (§4.4); tensor-MP factorizations are searched
    for the other families."""
    return cfg.family != "rnn"


def comm_runtime_supported(cfg: ModelConfig) -> bool:
    """Does the overlapped collective runtime have an executable tensor-MP
    path for this arch?  The SAME arch predicate the runtime gates on
    (``models.transformer.overlapped_arch_supported`` — homogeneous dense
    decoder blocks) plus the gate-major BigLSTM layer; everything else
    falls back to GSPMD at runtime, so the planner must not credit it with
    the matmul overlap (the bucketed DP grad sync is arch-independent and
    stays available to every pure-DP point)."""
    from repro.models.transformer import overlapped_arch_supported
    return cfg.name == "biglstm" or overlapped_arch_supported(cfg)


def grad_bytes(cfg: ModelConfig) -> float:
    return 4.0 * cfg.n_params()          # f32 gradients, paper-style sync-SGD


def step_time_single(cfg: ModelConfig, mini_batch: int, seq: int,
                     hw: HardwareModel) -> float:
    return 6.0 * cfg.n_active_params() * mini_batch * seq / (hw.peak_flops * hw.mfu)


def per_device_mem_bytes(cfg: ModelConfig, *, mp: int = 1,
                         mp_kind: str = "tensor", fsdp: int = 1,
                         mini_batch: int, seq_len: int,
                         opt_bytes_per_param: float = 8.0,
                         remat: bool = True, microbatches: int = 1,
                         schedule: str = "gpipe",
                         virtual_stages: int = 1,
                         pipe_runtime: str = "scheduled") -> float:
    """Projected per-device working set of one training step.

    f32 master params + optimizer state shard over (mp x fsdp); gradients
    shard over mp, and over fsdp too when it is on (ZeRO-2: grads are
    reduce-scattered, never fully materialized per rank); boundary
    activations kept by remat shard over the model axis for tensor-MP.

    Pipeline-MP activations are **schedule-aware** and keyed off the
    runtime that will execute the plan: each in-flight micro-batch holds
    keep_per_layer boundaries for this stage's L/mp layers, and the
    schedule bounds how many micro-batches are in flight
    (``pipeline_activation_residency``: K for gpipe — the full mini-batch,
    the seed's flat model — but only min(K, S) for 1f1b, which is what lets
    1f1b run micro-batch counts gpipe cannot fit).  That bound is only real
    on the hand-scheduled runtime (``pipe_runtime="scheduled"``); the
    AD-through-scan runtime holds all K boundaries for every schedule, so
    planning for it must cost K.
    """
    p = float(cfg.n_params())
    # context-parallel replicates params/opt/grads across the ring (only
    # activations shard 1/mp — CP is the axis to buy when the SEQUENCE is
    # what blows the budget, not the parameters)
    mp_param_shard = 1.0 if mp_kind == "context" else float(max(mp, 1))
    shard = mp_param_shard * max(fsdp, 1)
    state = (4.0 + opt_bytes_per_param) * p / shard
    grads = 4.0 * p / shard
    tokens = float(mini_batch) * float(seq_len)
    boundary = tokens * cfg.d_model * 2.0            # one bf16 (b, s, d)
    keep_per_layer = 1.0 if remat else 8.0           # remat keeps boundaries
    if mp_kind == "pipeline":
        k = max(microbatches, 1)
        per_micro = boundary / k                     # one micro-batch (b/K,s,d)
        resid = pipeline_activation_residency(k, max(mp, 1), schedule,
                                              virtual_stages,
                                              runtime=pipe_runtime)
        act = keep_per_layer * (cfg.n_layers / max(mp, 1)) * per_micro * resid
        # ring in/out buffers, plus the scheduled runtime's up-to-(v-1)
        # in-transit wrap chunks (plan_scheduled_runtime measures them);
        # v = 1 keeps the historical 2-buffer term
        act += (1.0 + max(virtual_stages, 1)) * per_micro
    else:
        act = keep_per_layer * cfg.n_layers * boundary / max(mp, 1)
    return state + grads + act


def default_opt_bytes_per_param(cfg: ModelConfig) -> float:
    """Adam (m + v, f32) for everything that fits; the giant archs train with
    factored adafactor state (see launch/dryrun.ADAFACTOR_ARCHS)."""
    return 1.0 if cfg.n_params() > 1e11 else 8.0


class HybridPlanner:
    """Unified search over every (pods, N, M, kind, K, schedule) point of
    the device budget: DP-only, N-way DP x M-way tensor-MP, N-way DP x
    M-stage pipeline-MP with K micro-batches under each feasible pipeline
    schedule (gpipe / 1f1b / interleaved), and N-way DP x M-device
    **context parallelism** (sequence-sharded ppermute KV rings,
    ``parallel.context`` — searched where the arch has the CP path and M
    divides the sequence; params replicated, so its memory filter shards
    only activations and its SE pays the full-gradient sync)."""

    def __init__(self, cfg: ModelConfig, *, epoch_model: EpochModel,
                 mini_batch: int = 16, seq_len: int = 4096,
                 dataset_tokens: int = 2 ** 33,
                 hw: HardwareModel = HardwareModel(),
                 se_perfect: bool = False,
                 mp_candidates: Tuple[int, ...] = (1, 2, 4, 8, 16, 32),
                 micro_candidates: Tuple[int, ...] = (2, 4, 8, 16),
                 remat: bool = True,
                 opt_bytes_per_param: Optional[float] = None,
                 pipe_runtime: str = "scheduled",
                 comm_runtime: str = "gspmd"):
        self.cfg = cfg
        self.hw = hw
        if pipe_runtime not in ("scheduled", "ad"):
            raise ValueError(f"unknown pipe_runtime {pipe_runtime!r}")
        if comm_runtime not in ("gspmd", "overlapped"):
            raise ValueError(f"unknown comm_runtime {comm_runtime!r}")
        # the runtime that will execute pipeline plans: the memory filter
        # must model what the executor actually holds live (the scheduled
        # runtime realizes each schedule's residency bound; AD-through-scan
        # holds all K micro-batches for every schedule)
        self.pipe_runtime = pipe_runtime
        # the collective runtime that will carry tensor-MP matmuls and the
        # DP grad sync: "overlapped" hides MEASURED_OVERLAP of the wire time
        # (chunked collective-matmul rings / bucketed reduce-scatter sync,
        # with the bucketed alpha cost charged), shifting both SU^M and
        # SE_N — and with them the DP-vs-hybrid crossover.  The matmul
        # overlap is only credited to archs the overlapped runtime actually
        # executes (comm_runtime_supported — everything else runs GSPMD's
        # monolithic collectives no matter what the plan asks for)
        self.comm_runtime = comm_runtime
        self.mp_comm_runtime = (comm_runtime if comm_runtime_supported(cfg)
                                else "gspmd")
        self.epoch_model = epoch_model
        self.mini_batch = mini_batch
        self.seq_len = seq_len
        self.se_perfect = se_perfect
        self.mp_candidates = mp_candidates
        self.micro_candidates = tuple(
            k for k in micro_candidates if k > 1 and mini_batch % k == 0)
        self.remat = remat
        self.opt_bytes_per_param = (default_opt_bytes_per_param(cfg)
                                    if opt_bytes_per_param is None
                                    else opt_bytes_per_param)
        self.pipe_candidates = pipeline_stage_candidates(cfg, mp_candidates)
        t1 = step_time_single(cfg, mini_batch, seq_len, hw)
        tensor_ms = (tuple(m for m in mp_candidates if m > 1)
                     if tensor_mp_supported(cfg) else ())
        # CP's feasibility filter is SEQUENCE divisibility, not heads: the
        # ring shards the token axis, so m must divide the training seq_len
        cp_ms = (tuple(m for m in mp_candidates
                       if m > 1 and seq_len % m == 0)
                 if context_mp_supported(cfg) else ())
        from repro.core.comm import MEASURED_OVERLAP
        from repro.parallel.collectives import DEFAULT_BUCKET_BYTES
        self.run = TrainingRun(
            name=cfg.name, t1=t1, grad_bytes=grad_bytes(cfg),
            mini_batch=mini_batch,
            epoch_model=epoch_model,
            dataset_size=dataset_tokens // seq_len,
            mp_speedup={m: mp_step_speedup(cfg, m, hw, self.mp_comm_runtime)
                        for m in tensor_ms},
            cp_speedup={m: cp_step_speedup(cfg, m, hw, mini_batch=mini_batch,
                                           seq_len=seq_len)
                        for m in cp_ms},
            hw=hw, se_perfect=se_perfect,
            comm_overlap=MEASURED_OVERLAP[comm_runtime],
            bucket_bytes=(DEFAULT_BUCKET_BYTES
                          if comm_runtime == "overlapped" else 0.0),
            pipe_speedup={(m, k, sched): pipeline_step_speedup_model(
                              cfg, m, k, hw, mini_batch=mini_batch,
                              seq_len=seq_len, schedule=sched,
                              virtual_stages=v)
                          for m in self.pipe_candidates
                          for k in self.micro_candidates
                          for sched, v in pipeline_schedule_candidates(
                              cfg, m, k)})

    # ---- search ------------------------------------------------------------

    def choices(self, total_devices: int) -> List[PlannerChoice]:
        """All memory-feasible strategy points for the budget, best first."""
        out: List[PlannerChoice] = []
        for m in self.mp_candidates:
            if total_devices % m:
                continue
            n = total_devices // m
            kinds: List[Tuple[str, int, str, int]] = []
            if m == 1:
                kinds.append(("none", 1, "-", 1))
            else:
                if m in self.run.mp_speedup:
                    kinds.append(("tensor", 1, "-", 1))
                if m in self.run.cp_speedup:
                    kinds.append(("context", 1, "-", 1))
                if m in self.pipe_candidates:
                    kinds.extend(
                        ("pipeline", k, sched, v)
                        for k in self.micro_candidates
                        for sched, v in pipeline_schedule_candidates(
                            self.cfg, m, k))
            for kind, k, sched, v in kinds:
                choice = self._evaluate(total_devices, n, m, kind, k, sched, v)
                if choice is not None:
                    out.append(choice)
        # deterministic order: best speedup first, then smaller MP, then the
        # cheaper-to-run kind, then fewer micro-batches; speedup ties between
        # schedules (gpipe vs 1f1b at the same (M, K) are *exactly* equal)
        # break toward the smaller per-device working set — more headroom at
        # identical projected step time
        return sorted(out, key=lambda c: (-c.speedup, c.mp, c.mp_kind,
                                          c.microbatches, c.mem_bytes,
                                          c.schedule))

    def _evaluate(self, total: int, n: int, m: int, kind: str, n_micro: int,
                  sched: str = "-", v: int = 1) -> Optional[PlannerChoice]:
        pipe = kind == "pipeline"
        ctx = kind == "context"
        mp_kind = "pipeline" if pipe else ("context" if ctx else "tensor")
        mem_kw = dict(
            mp=m, mp_kind=mp_kind,
            mini_batch=self.mini_batch, seq_len=self.seq_len,
            opt_bytes_per_param=self.opt_bytes_per_param, remat=self.remat,
            microbatches=n_micro if pipe else 1,
            schedule=sched if pipe else "gpipe",
            virtual_stages=v if pipe else 1,
            pipe_runtime=self.pipe_runtime)
        mem = per_device_mem_bytes(self.cfg, fsdp=1, **mem_kw)
        fsdp = False
        if mem > self.hw.hbm_bytes and n > 1:
            mem = per_device_mem_bytes(self.cfg, fsdp=n, **mem_kw)
            fsdp = True
        if mem > self.hw.hbm_bytes:
            return None                           # pruned: does not fit
        if pipe:
            su = speedup_pipeline(self.run, n, m, n_micro, sched)
            su_m = self.run.pipe_speedup.get((m, n_micro, sched), 0.0)
        elif ctx:
            su = speedup_context(self.run, n, m)
            su_m = self.run.cp_speedup.get(m, 0.0)
        elif kind == "tensor":
            su = speedup_hybrid(self.run, n, m)
            su_m = self.run.mp_speedup.get(m, 1.0)
        else:
            su = speedup_dp(self.run, n)
            su_m = 1.0
        pods = self._pods(total, n)
        dp_axes = ("pod", "data") if pods > 1 else ("data",)
        # stamp each plan with the comm runtime that will actually carry it:
        # pure-DP points get the (arch-independent) bucketed sync, tensor
        # points the matmul rings iff the arch has the overlapped path,
        # pipeline/context points their own ppermute rings (comm_runtime
        # inert for pipeline; the KV ring IS context's comm schedule)
        if pipe or ctx:
            point_comm = "gspmd"
        elif m > 1:
            point_comm = self.mp_comm_runtime
        else:
            point_comm = self.comm_runtime
        plan = ParallelPlan(
            dp_axes=dp_axes,
            model_axis="model" if m > 1 else None,
            fsdp_axes=dp_axes if fsdp else (),
            mp_kind=mp_kind,
            microbatches=n_micro if pipe else 1,
            schedule=sched if pipe else "gpipe",
            virtual_stages=v if pipe else 1,
            runtime=self.pipe_runtime,
            comm_runtime=point_comm,
            remat=self.remat)
        mesh_shape = (pods, n // pods, m) if pods > 1 else (n, m)
        return PlannerChoice(
            pods=pods, dp=n // pods, mp=m, mp_kind=kind,
            microbatches=n_micro if pipe else 1,
            schedule=sched if pipe else "-",
            virtual_stages=v if pipe else 1,
            speedup=su, su_m=su_m,
            se_n=self._se(n, m, context=ctx),
            epochs_ratio=self._eratio(n), mem_bytes=mem,
            mesh_shape=mesh_shape, plan=plan)

    def _pods(self, total: int, n: int) -> int:
        pods = max(1, total // self.hw.chips_per_pod)
        return pods if (total % self.hw.chips_per_pod == 0
                        and n % pods == 0) else 1

    def best(self, total_devices: int) -> PlannerChoice:
        cs = self.choices(total_devices)
        if not cs:
            raise ValueError(
                f"{self.cfg.name}: no memory-feasible strategy for "
                f"{total_devices} devices ({self.hw.hbm_bytes / 2**30:.0f} "
                f"GiB/device)")
        return cs[0]

    def _se(self, n: int, m: int = 1, context: bool = False) -> float:
        from repro.core.analytical import se
        if context:
            # params replicated across the ring: full grad bytes over all
            # n*m devices (speedup_context uses the same evaluation)
            return se(self.run, n * m, grad_scale=1.0, hybrid=True)
        return se(self.run, n, grad_scale=1.0 / max(m, 1), hybrid=m > 1)

    def _eratio(self, n: int) -> float:
        from repro.core.analytical import epochs_ratio
        return epochs_ratio(self.run, n)

    def crossover(self, m: int = 2, max_devices: int = 4096) -> Optional[int]:
        from repro.core.analytical import crossover_device_count
        return crossover_device_count(self.run, m, max_devices)

    # ---- inference-plan search (latency-SLO-constrained) -------------------

    def inference_choices(self, total_devices: int, *, slo_ms: float,
                          context: Optional[int] = None,
                          slot_candidates: Tuple[int, ...] = (
                              1, 2, 4, 8, 16, 32, 64, 128, 256),
                          comm_chunks: int = 1) -> List["InferenceChoice"]:
        """All (DP replicas x TP, slots) serving layouts meeting the
        per-token latency SLO, best sustained tokens/s first.

        The device budget factors into ``replicas`` independent decode
        groups of ``tp`` chips each (SplitBrain's hybrid worker layout);
        for each feasible tp this grows the slot count while the modeled
        decode-step latency stays under ``slo_ms`` and the weights + slot
        KV fit in HBM — both are monotone in slots, so the largest feasible
        count is the per-tp throughput argmax.  Tensor-MP is only searched
        for archs with a tensor path (``tensor_mp_supported``), and the
        ring-overlap credit only where the overlapped runtime actually
        executes (``self.mp_comm_runtime`` — same gate as training)."""
        context = self.seq_len if context is None else context
        out: List[InferenceChoice] = []
        tps = sorted({1, *self.mp_candidates})
        for tp in tps:
            if tp < 1 or total_devices % tp:
                continue
            if tp > 1 and not tensor_mp_supported(self.cfg):
                continue
            if tp > 1 and self.cfg.n_heads % tp:
                continue
            replicas = total_devices // tp
            weight_bytes = 2.0 * self.cfg.n_params() / tp   # bf16 serving
            if weight_bytes > self.hw.hbm_bytes:
                continue
            best = None
            for slots in sorted(slot_candidates):
                t_step = decode_step_time(
                    self.cfg, tp, self.hw, slots=slots, context=context,
                    comm_runtime=self.mp_comm_runtime if tp > 1 else "gspmd")
                mem = weight_bytes + kv_bytes(self.cfg, slots, context) / tp
                if t_step * 1e3 > slo_ms or mem > self.hw.hbm_bytes:
                    break                       # both monotone in slots
                best = (slots, t_step, mem)
            if best is None:
                continue
            slots, t_step, mem = best
            comm = self.mp_comm_runtime if tp > 1 else "gspmd"
            out.append(InferenceChoice(
                replicas=replicas, tp=tp, slots=slots,
                step_latency=t_step,
                tokens_per_s=replicas * slots / t_step,
                mem_bytes=mem,
                mesh_shape=(replicas if replicas > 1 else 1, tp),
                plan=serve_plan(tp, comm_runtime=comm,
                                comm_chunks=comm_chunks)))
        return sorted(out, key=lambda c: (-c.tokens_per_s, c.tp))

    def best_inference(self, total_devices: int, *, slo_ms: float,
                       context: Optional[int] = None,
                       **kw) -> "InferenceChoice":
        cs = self.inference_choices(total_devices, slo_ms=slo_ms,
                                    context=context, **kw)
        if not cs:
            raise ValueError(
                f"{self.cfg.name}: no serving layout over {total_devices} "
                f"devices meets a {slo_ms:g} ms/token SLO at context "
                f"{context if context is not None else self.seq_len} "
                f"({self.hw.hbm_bytes / 2**30:.0f} GiB/device) — raise the "
                f"SLO, shrink the context, or add devices")
        return cs[0]


def default_epoch_model(cfg: ModelConfig, mini_batch: int = 16) -> EpochModel:
    """Generic LM epoch-inflation prior: critical batch ~ 2-4M tokens for the
    ~1B archs, scaled by sqrt(params) (McCandlish-style heuristic)."""
    b_crit_tokens = 2e6 * math.sqrt(max(cfg.n_active_params(), 1e8) / 1e9)
    return EpochModel(e_inf=1.0, b_crit=b_crit_tokens / 4096, alpha=2.0)
