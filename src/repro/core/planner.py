"""HybridPlanner — the paper's contribution as a first-class feature.

Given an architecture config, a device budget, and hardware constants, the
planner (a) builds a per-step cost model from the arch's FLOPs/bytes,
(b) derives SE_N from the ring-all-reduce model, (c) takes E(B) from measured
curves or the fitted inflation model, and (d) evaluates Eq. 4 vs Eq. 5 over
every factorization (pods, N, M) of the budget, returning the arg-max as an
executable ``ParallelPlan`` + mesh shape.  ``launch/train.py --parallel auto``
calls this; explicit ``--parallel dp=16,mp=16`` overrides it.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.core.analytical import TrainingRun, speedup_hybrid
from repro.core.comm import HardwareModel, hierarchical_all_reduce_time
from repro.core.stateff import EpochModel, fit_epoch_model
from repro.parallel.plan import ParallelPlan


@dataclasses.dataclass(frozen=True)
class PlannerChoice:
    pods: int
    dp: int
    mp: int
    speedup: float                 # projected SU over a single device (Eq. 5)
    su_m: float                    # per-step MP speedup used
    se_n: float
    epochs_ratio: float
    mesh_shape: Tuple[int, ...]
    plan: ParallelPlan


def mp_step_speedup(cfg: ModelConfig, m: int, hw: HardwareModel) -> float:
    """SU^M for tensor-MP on the ICI torus: compute scales 1/m, plus the
    per-layer all-reduce of the (b, s, d) activations (2 per layer fwd, 2 bwd,
    Megatron pattern).  Uses bytes/FLOP analytics per arch family — the TPU
    analogue of the paper's measured Table 1 / DLPlacer estimates."""
    if m <= 1:
        return 1.0
    # reference per-device micro-batch: 16 sequences of 4k tokens
    b, s = 16, 4096
    tokens = b * s
    flops = 6.0 * cfg.n_active_params() / cfg.n_layers * tokens  # per layer
    t_layer = flops / (hw.peak_flops * hw.mfu)
    act_bytes = tokens * cfg.d_model * 2
    n_ar = 4  # 2 fwd + 2 bwd all-reduces per layer (attn + mlp row-parallel)
    t_ar = n_ar * 2.0 * (m - 1) / m * act_bytes / hw.ici_bw
    return (t_layer) / (t_layer / m + t_ar)


def grad_bytes(cfg: ModelConfig) -> float:
    return 4.0 * cfg.n_params()          # f32 gradients, paper-style sync-SGD


def step_time_single(cfg: ModelConfig, mini_batch: int, seq: int,
                     hw: HardwareModel) -> float:
    return 6.0 * cfg.n_active_params() * mini_batch * seq / (hw.peak_flops * hw.mfu)


class HybridPlanner:
    """Evaluates every (pods, dp, mp) factorization of the device budget."""

    def __init__(self, cfg: ModelConfig, *, epoch_model: EpochModel,
                 mini_batch: int = 16, seq_len: int = 4096,
                 dataset_tokens: int = 2 ** 33,
                 hw: HardwareModel = HardwareModel(),
                 se_perfect: bool = False,
                 mp_candidates: Tuple[int, ...] = (1, 2, 4, 8, 16, 32)):
        self.cfg = cfg
        self.hw = hw
        self.epoch_model = epoch_model
        self.mini_batch = mini_batch
        self.seq_len = seq_len
        self.se_perfect = se_perfect
        self.mp_candidates = mp_candidates
        t1 = step_time_single(cfg, mini_batch, seq_len, hw)
        self.run = TrainingRun(
            name=cfg.name, t1=t1, grad_bytes=grad_bytes(cfg),
            mini_batch=mini_batch,
            epoch_model=epoch_model,
            dataset_size=dataset_tokens // seq_len,
            mp_speedup={m: mp_step_speedup(cfg, m, hw)
                        for m in mp_candidates if m > 1},
            hw=hw, se_perfect=se_perfect)

    def choices(self, total_devices: int) -> List[PlannerChoice]:
        out = []
        for m in self.mp_candidates:
            if total_devices % m:
                continue
            n = total_devices // m
            su = speedup_hybrid(self.run, n, m)
            pods = max(1, total_devices // self.hw.chips_per_pod)
            dp_in_pod = n // pods if n % max(pods, 1) == 0 else n
            se_n = (1.0 if self.se_perfect else
                    self._se(n))
            out.append(PlannerChoice(
                pods=pods, dp=n // pods if n % pods == 0 else n, mp=m,
                speedup=su,
                su_m=self.run.mp_speedup.get(m, 1.0) if m > 1 else 1.0,
                se_n=se_n,
                epochs_ratio=self._eratio(n),
                mesh_shape=((pods, n // pods, m) if pods > 1 else (n, m)),
                plan=ParallelPlan(
                    dp_axes=("pod", "data") if pods > 1 else ("data",),
                    model_axis="model" if m > 1 else None),
            ))
        return sorted(out, key=lambda c: -c.speedup)

    def best(self, total_devices: int) -> PlannerChoice:
        return self.choices(total_devices)[0]

    def _se(self, n: int) -> float:
        from repro.core.analytical import se
        return se(self.run, n)

    def _eratio(self, n: int) -> float:
        from repro.core.analytical import epochs_ratio
        return epochs_ratio(self.run, n)

    def crossover(self, m: int = 2, max_devices: int = 4096) -> Optional[int]:
        from repro.core.analytical import crossover_device_count
        return crossover_device_count(self.run, m, max_devices)


def default_epoch_model(cfg: ModelConfig, mini_batch: int = 16) -> EpochModel:
    """Generic LM epoch-inflation prior: critical batch ~ 2-4M tokens for the
    ~1B archs, scaled by sqrt(params) (McCandlish-style heuristic)."""
    b_crit_tokens = 2e6 * math.sqrt(max(cfg.n_active_params(), 1e8) / 1e9)
    return EpochModel(e_inf=1.0, b_crit=b_crit_tokens / 4096, alpha=2.0)
