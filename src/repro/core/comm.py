"""Communication cost models (paper §3.1, §3.4 inputs).

Ring all-reduce time (Thakur et al. 2005; Patarasuk & Yuan 2009) over N
devices for B bytes:  t = 2 * (N-1)/N * B / bw + (N-1) * latency — the model
behind the paper's scaling-efficiency term SE_N, which it conservatively set
to 1; we compute it (and also expose the SE_N=1 mode for the paper-faithful
reproduction).

Hierarchical topologies: intra-pod ICI rings vs pod-crossing DCI — the
bandwidth cliff that makes SE_{M*N}/SE_N < 1 at pod boundaries, which is
exactly the regime where the paper's hybrid strategy wins (Eq. 6).
"""
from __future__ import annotations

import dataclasses
import json
import math
import os

from repro.core.roofline import (DCI_BW, HBM_PER_CHIP, ICI_LINKS, LINK_BW,
                                 PEAK_FLOPS)


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """Per-chip hardware constants + topology (TPU v5e pod defaults)."""

    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = 819e9
    ici_bw: float = ICI_LINKS * LINK_BW   # all usable torus links
    dci_bw: float = DCI_BW                # inter-pod per chip
    ici_latency: float = 1e-6
    dci_latency: float = 10e-6
    chips_per_pod: int = 256
    mfu: float = 0.45                     # achievable fraction of peak in T_1
    hbm_bytes: float = HBM_PER_CHIP      # per-device memory budget


# Fraction of collective time hidden under partial-matmul compute / backward
# compute for each collective runtime (parallel.collectives): the GSPMD
# monolithic all-reduce is fully exposed; the chunked ppermute rings and the
# bucketed DP sync overlap most of theirs.  The "overlapped" entry is LOADED
# from the bench artifact when one exists (calibration is a measurement, not
# a constant): benchmarks/collective_overlap_sweep.py emits
# BENCH_collectives.json with ``tensor_mp.overlap_constant_proxy`` — the
# fraction of the GSPMD step's comm time the overlapped rings actually hid
# on this host's mesh.  The 0.6 constant is the fallback for a fresh
# checkout / CI runner with no artifact; re-measure on real ICI hardware.
_OVERLAP_FALLBACK = 0.6


def _repo_root() -> str:
    # src/repro/core/comm.py -> repo root (where the bench artifacts land)
    return os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))


def load_measured_overlap(path: str | None = None) -> dict:
    """{"gspmd": 0.0, "overlapped": <measured|fallback>} — the overlapped
    entry read from ``BENCH_collectives.json``'s
    ``tensor_mp.overlap_constant_proxy`` when the artifact exists (repo
    root by default), else the ``_OVERLAP_FALLBACK`` constant.  Clamped to
    [0, 0.95]: a degenerate measurement must not let the planner cost
    collectives as free (or negative)."""
    p = path or os.environ.get("REPRO_BENCH_COLLECTIVES",
                               os.path.join(_repo_root(),
                                            "BENCH_collectives.json"))
    overlapped = _OVERLAP_FALLBACK
    try:
        with open(p) as f:
            proxy = json.load(f)["tensor_mp"]["overlap_constant_proxy"]
        overlapped = min(max(float(proxy), 0.0), 0.95)
    except (OSError, KeyError, TypeError, ValueError):
        pass
    return {"gspmd": 0.0, "overlapped": overlapped}


MEASURED_OVERLAP = load_measured_overlap()


def ring_all_reduce_time(bytes_: float, n: int, bw: float,
                         latency: float) -> float:
    """Bandwidth term + the latency (alpha) term: (n-1) hops of the ring,
    each paying one launch/rendezvous latency — without it the model is a
    pure bandwidth term that understates small transfers (and lets the
    planner pick arbitrarily small buckets / micro-batches for free)."""
    if n <= 1:
        return 0.0
    return 2.0 * (n - 1) / n * bytes_ / bw + (n - 1) * latency


def bucketed_all_reduce_time(bytes_: float, n: int, bw: float, latency: float,
                             bucket_bytes: float) -> float:
    """Ring all-reduce split into ceil(bytes/bucket) reduce-scatter +
    all-gather bucket pairs (``parallel.collectives.bucketed_grad_sync``):
    the wire bytes are unchanged but every bucket pays its own 2*(n-1) hop
    latencies — the alpha cost of bucketing that the overlap win must beat
    (this is what penalizes tiny buckets in the planner)."""
    if n <= 1:
        return 0.0
    n_buckets = max(1, math.ceil(bytes_ / max(bucket_bytes, 1.0)))
    return (2.0 * (n - 1) / n * bytes_ / bw
            + n_buckets * 2.0 * (n - 1) * latency)


def p2p_transfer_time(bytes_: float, hw: HardwareModel, *,
                      inter_pod: bool = False) -> float:
    """Point-to-point neighbor transfer (``ppermute`` between adjacent
    pipeline stages): one hop over a single direction of the torus."""
    if inter_pod:
        return bytes_ / hw.dci_bw + hw.dci_latency
    # a stage boundary uses the links toward one neighbor, not the full torus
    per_hop_bw = hw.ici_bw / ICI_LINKS
    return bytes_ / per_hop_bw + hw.ici_latency


def cp_ring_time(hop_bytes: float, m: int, hw: HardwareModel, *,
                 rings: float = 3.0, inter_pod: bool = False) -> float:
    """Per-layer wire time of the context-parallel KV ring
    (``parallel.context.ring_attention``): ``m - 1`` neighbor ``ppermute``
    hops, each carrying one sequence shard's bf16 K+V block over a single
    torus direction (``p2p_transfer_time``: per-hop bandwidth + the alpha
    launch latency that dominates small shards).  ``rings`` counts the
    rotations per train step: 1 forward (KV) + 2 backward (KV again, and
    the dK/dV accumulators riding the ring home) = 3."""
    if m <= 1:
        return 0.0
    return rings * (m - 1) * p2p_transfer_time(hop_bytes, hw,
                                               inter_pod=inter_pod)


def hierarchical_all_reduce_time(bytes_: float, n: int, hw: HardwareModel,
                                 intra_pod_degree: int,
                                 bucket_bytes: float = 0.0) -> float:
    """reduce-scatter intra-pod, all-reduce across pods, all-gather intra-pod.

    ``bucket_bytes`` > 0 models the bucketed runtime
    (``parallel.collectives.bucketed_grad_sync``): the intra-pod phases pay
    per-bucket hop latencies instead of one fused ring's."""
    def intra(b: float, k: int) -> float:
        if bucket_bytes > 0:
            return bucketed_all_reduce_time(b, k, hw.ici_bw, hw.ici_latency,
                                            bucket_bytes)
        return ring_all_reduce_time(b, k, hw.ici_bw, hw.ici_latency)

    if n <= intra_pod_degree:
        return intra(bytes_, n)
    n_pods = n // intra_pod_degree
    t_intra = intra(bytes_, intra_pod_degree)
    t_inter = ring_all_reduce_time(bytes_ / intra_pod_degree, n_pods,
                                   hw.dci_bw, hw.dci_latency)
    return t_intra + t_inter


def scaling_efficiency(grad_bytes: float, step_compute_time: float, n: int,
                       hw: HardwareModel, *, overlap: float = 0.0,
                       bucket_bytes: float = 0.0,
                       assume_perfect: bool = False) -> float:
    """SE_N = T_1 / T_N for N-way DP (paper §3.1).

    ``assume_perfect`` reproduces the paper's conservative SE_N = 1.
    ``overlap`` in [0,1): fraction of the gradient exchange hidden under
    backward compute (0 for the monolithic GSPMD all-reduce;
    ``MEASURED_OVERLAP["overlapped"]`` for the bucketed sync, whose
    ``bucket_bytes`` also charges the per-bucket alpha cost).
    """
    if assume_perfect or n <= 1:
        return 1.0
    t_ar = hierarchical_all_reduce_time(grad_bytes, n, hw, hw.chips_per_pod,
                                        bucket_bytes=bucket_bytes)
    t_ar *= (1.0 - overlap)
    return step_compute_time / (step_compute_time + t_ar)
