"""The paper's contribution: analytical hybrid-parallelism framework,
DLPlacer, and the roofline machinery."""
from repro.core.analytical import (TrainingRun, best_strategy,
                                   crossover_device_count, hybrid_wins,
                                   speedup_dp, speedup_hybrid)
from repro.core.comm import HardwareModel, ring_all_reduce_time, scaling_efficiency
from repro.core.planner import HybridPlanner, default_epoch_model
from repro.core.stateff import EpochModel, fit_epoch_model, paper_epoch_model

__all__ = ["TrainingRun", "best_strategy", "crossover_device_count",
           "hybrid_wins", "speedup_dp", "speedup_hybrid", "HardwareModel",
           "ring_all_reduce_time", "scaling_efficiency", "HybridPlanner",
           "default_epoch_model", "EpochModel", "fit_epoch_model",
           "paper_epoch_model"]
