"""Roofline analysis from compiled dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape x mesh), all in seconds:

    compute    = HLO_FLOPs      / (chips * PEAK_FLOPS)
    memory     = HLO_bytes      / (chips * HBM_BW)
    collective = collective_bytes / (chips * n_links * LINK_BW)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (per-partition
under GSPMD, so they are already per-chip — we multiply back to totals for
reporting).  collective_bytes is parsed from the HLO text: every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute op's tensor
sizes, weighted by the ring-algorithm wire factor for its replica-group size.

Hardware constants: TPU v5e-like — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link
ICI_LINKS = 4                # links/chip usable on the 2D torus (x+/x-/y+/y-)
DCI_BW = 25e9                # inter-pod (data-center interconnect) per chip pair
HBM_PER_CHIP = 16 * 1024**3  # v5e: 16 GiB

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _tensor_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return default


def _wire_factor(op: str, g: int) -> float:
    """Ring-algorithm bytes-on-wire per participating chip, as a multiple of
    the (per-shard) tensor bytes."""
    if g <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * (g - 1) / g
    if op in ("all-gather", "reduce-scatter", "all-to-all"):
        return (g - 1) / g
    return 1.0  # collective-permute: one hop


@dataclasses.dataclass
class CollectiveStats:
    ops: Dict[str, int]
    wire_bytes: float            # per-chip bytes on the wire (ring model)
    tensor_bytes: float          # raw summed tensor bytes (reported too)
    lines: List[str]

    def to_dict(self):
        return {"ops": self.ops, "wire_bytes": self.wire_bytes,
                "tensor_bytes": self.tensor_bytes}


def parse_collectives(hlo_text: str, default_group: int,
                      multiplier_fn=None) -> CollectiveStats:
    """Scan HLO text and sum collective traffic.

    ``multiplier_fn(computation_name) -> int`` lets callers weight while-body
    computations by trip count; by default everything counts once (the dry-run
    lowers with unrolled layer stacks so this is exact — DESIGN.md §5).
    """
    ops: Dict[str, int] = {}
    wire = 0.0
    raw = 0.0
    lines_kept: List[str] = []
    current_comp = ""
    # "%name = <type> all-reduce(...)" — capture the result type between the
    # "=" and the op mnemonic (may be a tuple for -start forms)
    inst_re = re.compile(
        r"=\s*(.*?)\s+(" + "|".join(_COLLECTIVES) + r")(-start)?\(")
    for line in hlo_text.splitlines():
        ls = line.strip()
        if (ls.startswith("%") and ls.endswith("{")) or ls.startswith("ENTRY"):
            current_comp = ls.split(" ")[0]
        m = inst_re.search(ls)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        if m.group(3):  # -start returns (operand, result[, scratch]) tuple:
            # halve to avoid double counting operand+result
            tb = _tensor_bytes(type_str) / 2
        else:
            tb = _tensor_bytes(type_str)
        mult = multiplier_fn(current_comp) if multiplier_fn else 1
        g = _group_size(ls, default_group)
        ops[op] = ops.get(op, 0) + mult
        raw += tb * mult
        wire += tb * _wire_factor(op, g) * mult
        lines_kept.append(ls[:200])
    return CollectiveStats(ops=ops, wire_bytes=wire, tensor_bytes=raw,
                           lines=lines_kept)


@dataclasses.dataclass
class Roofline:
    chips: int
    hlo_flops_per_chip: float
    hlo_bytes_per_chip: float
    collective_wire_bytes_per_chip: float
    model_flops_total: float
    crosses_pod: bool = False

    @property
    def t_compute(self) -> float:
        return self.hlo_flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        bw = ICI_LINKS * LINK_BW if not self.crosses_pod else DCI_BW
        return self.collective_wire_bytes_per_chip / bw

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total_hlo = self.hlo_flops_per_chip * self.chips
        return self.model_flops_total / total_hlo if total_hlo else 0.0

    @property
    def step_time(self) -> float:
        """Simple max-of-terms bound (perfect overlap assumption)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def mfu(self) -> float:
        t = self.step_time
        if not t:
            return 0.0
        return self.model_flops_total / (self.chips * PEAK_FLOPS * t)

    def to_dict(self):
        return {
            "chips": self.chips,
            "hlo_flops_per_chip": self.hlo_flops_per_chip,
            "hlo_bytes_per_chip": self.hlo_bytes_per_chip,
            "collective_wire_bytes_per_chip": self.collective_wire_bytes_per_chip,
            "model_flops_total": self.model_flops_total,
            "t_compute": self.t_compute,
            "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "step_time": self.step_time,
            "mfu": self.mfu,
        }


def model_flops(cfg, shape, kind: Optional[str] = None) -> float:
    """MODEL_FLOPS: 6*N*D for training (N = active params, D = tokens);
    2*N*D for inference forward; attention's quadratic term added explicitly
    (it is not in N*D)."""
    kind = kind or shape.kind
    n_active = cfg.n_active_params()
    if kind == "train":
        tokens = shape.seq_len * shape.global_batch
        base = 6.0 * n_active * tokens
        mult = 3.0
    elif kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        base = 2.0 * n_active * tokens
        mult = 1.0
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        base = 2.0 * n_active * tokens
        mult = 1.0
    # attention score/value FLOPs: 2 * 2 * B * S_kv * T_q * H * hd per layer
    if cfg.n_heads and not cfg.rwkv:
        window = cfg.sliding_window
        if kind == "decode" and shape.seq_len > 65536 and not window:
            window = cfg.long_context_window
        s_kv = min(shape.seq_len, window) if window else shape.seq_len
        if kind == "decode":
            t_q = 1
            s_eff = s_kv
        else:
            t_q = shape.seq_len
            s_eff = (s_kv + 1) / 2 if not window else min(window, shape.seq_len)
        attn = (4.0 * shape.global_batch * t_q * s_eff
                * cfg.n_heads * cfg.head_dim * cfg.n_layers)
        base += mult * attn
    return base
