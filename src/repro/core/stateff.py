"""Statistical efficiency: epochs-to-converge E(B) versus global batch size
(paper §3.1, Fig. 4).

Two sources, mirroring the paper's methodology:

1. **Measured**: ``measure_epochs_to_converge`` trains a real (small) model on
   a synthetic-but-learnable task at different global batch sizes, using the
   paper's §4.2 delayed-gradient trick to emulate batch sizes larger than the
   physical device count, and records epochs until the loss target.  This is
   what benchmarks/fig4_epochs.py runs on CPU.

2. **Fitted model**: E(B) = E_inf * (1 + (B / B_crit)^alpha) — the
   critical-batch-size form (Shallue et al. / McCandlish et al.), fitted to
   measured points, plus calibration tables digitized from the paper's Fig. 4
   so the planner can reproduce the paper's Inception-V3 / GNMT / BigLSTM
   projections exactly.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class EpochModel:
    """E(B) = e_inf * (1 + (B / b_crit) ** alpha), clipped at b_max where the
    paper reports divergence ("did not converge in meaningful time")."""

    e_inf: float
    b_crit: float
    alpha: float = 2.0
    b_max: Optional[float] = None

    def epochs(self, global_batch: float) -> float:
        if self.b_max is not None and global_batch > self.b_max:
            return float("inf")
        return self.e_inf * (1.0 + (global_batch / self.b_crit) ** self.alpha)

    def ratio(self, b1: float, b2: float) -> float:
        """E(b1) / E(b2) — the paper's E_N / E_{M*N} style terms."""
        return self.epochs(b1) / self.epochs(b2)


# --- calibration: digitized from the paper's Fig. 4 (epochs vs GPUs) -------
# mini-batch per GPU: Inception-V3 = 64, GNMT = 128, BigLSTM = 128.
PAPER_FIG4: Dict[str, Dict[int, float]] = {
    # global batch -> epochs
    "inception_v3": {512: 4, 1024: 4, 2048: 4.0, 4096: 7, 8192: 12, 16384: 23},
    "gnmt": {256: 5.5, 512: 5.0, 1024: 5.0, 2048: 5.2, 4096: 5.5, 8192: 6.5,
             16384: 9.0, 32768: 17.0},
    "biglstm": {512: 5.0, 1024: 5.5, 2048: 6.5, 4096: 21.0},
}
PAPER_MINI_BATCH = {"inception_v3": 64, "gnmt": 128, "biglstm": 128}


@dataclasses.dataclass(frozen=True)
class EpochTable:
    """Exact E(B) lookup over digitized points with geometric interpolation —
    used to replay the paper's own Fig. 5 projections without smoothing
    error (the fitted EpochModel is for planner extrapolation)."""

    points: tuple                      # ((batch, epochs), ...) sorted
    b_max: Optional[float] = None

    @classmethod
    def from_dict(cls, d: Dict[int, float], b_max=None) -> "EpochTable":
        return cls(tuple(sorted(d.items())), b_max)

    def epochs(self, global_batch: float) -> float:
        if self.b_max is not None and global_batch > self.b_max:
            return float("inf")
        pts = self.points
        if global_batch <= pts[0][0]:
            return pts[0][1]
        if global_batch >= pts[-1][0]:
            # extrapolate with the final segment's log-log slope
            (b0, e0), (b1, e1) = pts[-2], pts[-1]
            slope = math.log(e1 / e0) / math.log(b1 / b0)
            return e1 * (global_batch / b1) ** slope
        for (b0, e0), (b1, e1) in zip(pts, pts[1:]):
            if b0 <= global_batch <= b1:
                f = math.log(global_batch / b0) / math.log(b1 / b0)
                return e0 * (e1 / e0) ** f
        raise AssertionError

    def ratio(self, b1: float, b2: float) -> float:
        return self.epochs(b1) / self.epochs(b2)


def paper_epoch_table(network: str) -> EpochTable:
    b_max = 4097.0 if network == "biglstm" else None
    return EpochTable.from_dict(PAPER_FIG4[network], b_max=b_max)


def fit_epoch_model(points: Dict[int, float], b_max: Optional[float] = None,
                    alphas: Sequence[float] = (1.0, 1.5, 2.0, 2.5, 3.0)) -> EpochModel:
    """Least-squares fit of (e_inf, b_crit) over a small alpha grid."""
    bs = np.array(sorted(points), dtype=np.float64)
    es = np.array([points[int(b)] for b in bs], dtype=np.float64)
    best = None
    e_inf0 = float(es.min())
    for alpha in alphas:
        for b_crit in np.geomspace(bs.min() / 2, bs.max() * 8, 64):
            pred_unit = 1.0 + (bs / b_crit) ** alpha
            e_inf = float((es * pred_unit).sum() / (pred_unit ** 2).sum())
            resid = float(((es - e_inf * pred_unit) ** 2).sum())
            if best is None or resid < best[0]:
                best = (resid, EpochModel(e_inf, float(b_crit), alpha, b_max))
    return best[1]


def paper_epoch_model(network: str) -> EpochModel:
    pts = PAPER_FIG4[network]
    b_max = 4096.0 if network == "biglstm" else None
    return fit_epoch_model(pts, b_max=b_max)


# --- measured-on-CPU convergence (fig4 benchmark) ---------------------------

def measure_epochs_to_converge(train_step_fn, init_state, data_epochs_fn,
                               *, target_loss: float, max_epochs: int,
                               accum: int = 1) -> float:
    """Train until mean epoch loss <= target; return (possibly fractional)
    epochs.  ``data_epochs_fn(epoch)`` yields the step batches of one epoch;
    ``accum`` emulates `accum`x larger global batch via delayed gradient
    update (paper §4.2) — the caller builds train_step_fn with that
    microbatch count.
    """
    state = init_state
    for epoch in range(max_epochs):
        losses = []
        for batch in data_epochs_fn(epoch):
            state, metrics = train_step_fn(state, batch)
            losses.append(float(metrics["loss"]))
        # mean loss over the trailing half of the epoch = current quality
        half = losses[len(losses) // 2:]
        cur = sum(half) / max(len(half), 1)
        if cur <= target_loss:
            # linear interpolation within the epoch for fractional credit
            below = [i for i, l in enumerate(losses) if l <= target_loss]
            frac = below[0] / len(losses) if below else 1.0
            return epoch + frac
    return float(max_epochs)
