"""Shared neural-net layers: norms, RoPE, GQA attention (full / sliding-window /
chunked-online-softmax), KV caches, and MLP variants.

Everything is a pure function over explicit param pytrees so that the parallel
runtime can assign `NamedSharding`s by param path and `jax.eval_shape` can
derive ShapeDtypeStructs for the multi-pod dry-run without allocating.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel.jaxcompat import shard_map

# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d), dtype=jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x, gamma, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * gamma.astype(jnp.float32)).astype(dt)


def layer_norm(x, gamma, beta, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., T, H, hd); positions: broadcastable to (..., T)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., T, hd/2)
    sin = jnp.sin(angles)[..., None, :]                 # (..., T, 1, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

NEG_INF = -1e30

# Trace-time switch: the dry-run's ANALYSIS artifacts set this so every scan
# fully unrolls and XLA's cost analysis counts all iterations (the HLO cost
# model visits while-loop bodies exactly once).  Never set during real runs.
_ANALYSIS_UNROLL = False


def set_analysis_unroll(value: bool) -> None:
    global _ANALYSIS_UNROLL
    _ANALYSIS_UNROLL = bool(value)


def analysis_unroll() -> bool:
    return _ANALYSIS_UNROLL


def repeat_kv(k, n_rep: int):
    """(B, S, KV, hd) -> (B, S, KV*n_rep, hd)."""
    if n_rep == 1:
        return k
    b, s, kv, hd = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, n_rep, hd))
    return k.reshape(b, s, kv * n_rep, hd)


def _dense_attention(q, k, v, mask, softcap: float = 0.0):
    """q: (B,Tq,H,hd) k,v: (B,Tk,H,hd) mask: (B,1,Tq,Tk) or None -> (B,Tq,H,hd)."""
    hd = q.shape[-1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(hd)
    if softcap:
        scores = softcap * jnp.tanh(scores / softcap)
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)


def _chunked_attention(q, k, v, q_start, causal: bool, window: int, kv_chunk: int):
    """Online-softmax attention scanning over KV chunks (flash-attention
    algorithm in pure jnp — memory O(Tq * kv_chunk), the oracle for the Pallas
    kernel).  q: (B,Tq,H,hd); k,v: (B,Tk,H,hd).  q position i = q_start + i.
    """
    b, tq, h, hd = q.shape
    tk = k.shape[1]
    n_chunks = (tk + kv_chunk - 1) // kv_chunk
    pad = n_chunks * kv_chunk - tk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, n_chunks, kv_chunk, h, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, kv_chunk, h, hd).transpose(1, 0, 2, 3, 4)
    q32 = q.astype(jnp.float32) / math.sqrt(hd)
    qpos = q_start + jnp.arange(tq)

    def step(carry, xs):
        m, l, acc = carry
        ci, kb, vb = xs
        kpos = ci * kv_chunk + jnp.arange(kv_chunk)
        s = jnp.einsum("bqhd,bkhd->bhqk", q32, kb.astype(jnp.float32))
        valid = kpos[None, :] < tk
        if causal:
            valid = valid & (kpos[None, :] <= qpos[:, None])
        if window > 0:
            valid = valid & (kpos[None, :] > qpos[:, None] - window)
        s = jnp.where(valid[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, tq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, tq), jnp.float32)
    acc0 = jnp.zeros((b, h, tq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0), (jnp.arange(n_chunks), kc, vc),
        unroll=n_chunks if _ANALYSIS_UNROLL else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def attention(q, k, v, *, causal: bool = True, q_start=0, window: int = 0,
              softcap: float = 0.0, kv_chunk: int = 1024,
              dense_threshold: int = 8192, kv_mask=None, mask=None):
    """GQA attention.  q: (B,Tq,Hq,hd); k,v: (B,Tk,Hkv,hd).

    ``window`` > 0 restricts key j to (i - window, i].  ``kv_mask`` is an
    optional (B, Tk) bool of valid cache slots (decode).  ``mask`` is an
    explicit (B, Tq, Tk) bool overriding all derived masking (per-request
    positions in the slotted serving cache); it forces the dense path.
    Otherwise chooses a dense path for short KV and the chunked
    online-softmax path (flash algorithm) for long KV.
    """
    hq, hkv = q.shape[2], k.shape[2]
    k = repeat_kv(k, hq // hkv)
    v = repeat_kv(v, hq // hkv)
    if mask is not None:
        return _dense_attention(q, k, v, mask[:, None], softcap)
    tq, tk = q.shape[1], k.shape[1]
    if tk <= dense_threshold or softcap:
        qpos = q_start + jnp.arange(tq)
        kpos = jnp.arange(tk)
        mask = jnp.ones((tq, tk), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window > 0:
            mask &= kpos[None, :] > qpos[:, None] - window
        mask = mask[None, None]
        if kv_mask is not None:
            mask = mask & kv_mask[:, None, None, :]
        return _dense_attention(q, k, v, mask, softcap)
    assert kv_mask is None, "chunked path expects a fully-valid cache"
    return _chunked_attention(q, k, v, q_start, causal, window, kv_chunk)


# ---------------------------------------------------------------------------
# KV cache (full-length buffer or sliding-window ring)
# ---------------------------------------------------------------------------

def make_kv_cache(batch: int, length: int, n_kv: int, head_dim: int, dtype):
    return {
        "k": jnp.zeros((batch, length, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, length, n_kv, head_dim), dtype),
    }


def cache_insert_full(cache, k_new, v_new, pos):
    """Write (B,1,KV,hd) at absolute position ``pos`` (scalar int)."""
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, pos, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, pos, axis=1)
    return {"k": k, "v": v}


def cache_insert_at(cache, k_new, v_new, pos):
    """Write (B,t,KV,hd) at per-row positions ``pos`` (B,) — one
    dynamic_update_slice per row (vmapped), the slotted-cache insert of the
    continuous-batching engine.  Scalar ``pos`` falls through to
    ``cache_insert_full``."""
    if jnp.ndim(pos) == 0:
        return cache_insert_full(cache, k_new, v_new, pos)
    upd = jax.vmap(
        lambda c, n, p: jax.lax.dynamic_update_slice_in_dim(c, n, p, axis=0))
    return {"k": upd(cache["k"], k_new, pos), "v": upd(cache["v"], v_new, pos)}


def cache_insert_window(cache, k_new, v_new):
    """Shift-left ring insert for sliding-window caches (keys stored roped)."""
    k = jnp.concatenate([cache["k"][:, 1:], k_new], axis=1)
    v = jnp.concatenate([cache["v"][:, 1:], v_new], axis=1)
    return {"k": k, "v": v}


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(key, d: int, d_ff: int, kind: str, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    if kind == "swiglu":
        return {"wi": dense_init(ks[0], d, d_ff, dtype),
                "wg": dense_init(ks[1], d, d_ff, dtype),
                "wo": dense_init(ks[2], d_ff, d, dtype)}
    return {"wi": dense_init(ks[0], d, d_ff, dtype),
            "wo": dense_init(ks[2], d_ff, d, dtype)}


def mlp_apply(params, x, kind: str):
    if kind == "swiglu":
        h = jax.nn.silu(x @ params["wg"].astype(x.dtype)) * (x @ params["wi"].astype(x.dtype))
    elif kind == "gelu":
        h = jax.nn.gelu(x @ params["wi"].astype(x.dtype))
    elif kind == "sqrelu":
        h = jnp.square(jax.nn.relu(x @ params["wi"].astype(x.dtype)))
    else:
        raise ValueError(kind)
    return h @ params["wo"].astype(x.dtype)


def mlp_apply_overlapped(params, x, kind: str, *, axis: str, axis_size: int,
                         chunks: int = 1):
    """Megatron column/row-parallel MLP on the overlap-scheduled collective
    rings (``parallel.collectives``), for use INSIDE a shard_map: ``x`` is
    (..., T/m, d) sequence-sharded over ``axis``; ``wi``/``wg`` are this
    shard's column slices, ``wo`` the row slice.  The gate and up projections
    share one gather ring (their weights are concatenated so x travels the
    ring once).  Returns (..., T/m, d) sequence-sharded."""
    from repro.parallel.collectives import (all_gather_matmul,
                                            matmul_reduce_scatter)
    kw = dict(axis=axis, axis_size=axis_size, chunks=chunks)
    if kind == "swiglu":
        ff = params["wi"].shape[1]
        w2 = jnp.concatenate([params["wg"], params["wi"]], axis=1)
        gi = all_gather_matmul(x, w2.astype(x.dtype), **kw)
        h = jax.nn.silu(gi[..., :ff]) * gi[..., ff:]
    elif kind == "gelu":
        h = jax.nn.gelu(all_gather_matmul(x, params["wi"].astype(x.dtype), **kw))
    elif kind == "sqrelu":
        h = jnp.square(jax.nn.relu(
            all_gather_matmul(x, params["wi"].astype(x.dtype), **kw)))
    else:
        raise ValueError(kind)
    return matmul_reduce_scatter(h, params["wo"].astype(x.dtype), **kw)


# ---------------------------------------------------------------------------
# sequence-sharded decode attention (flash-decode, §Perf iteration B.2)
# ---------------------------------------------------------------------------

def _partial_softmax_stats(q, k, v, valid):
    """q: (B,1,H,hd); k,v: (B,C,H,hd); valid: (B,C) -> (m, l, acc) in f32.

    m: (B,H); l: (B,H); acc: (B,H,hd) — mergeable partial softmax stats.
    """
    import math as _math
    hd = q.shape[-1]
    s = jnp.einsum("bqhd,bkhd->bhk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / _math.sqrt(hd)
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(axis=-1)
    acc = jnp.einsum("bhk,bkhd->bhd", p, v.astype(jnp.float32))
    return m, l, acc


def merge_softmax_stats(stats_a, stats_b):
    """Merge two partial-softmax stats triples (flash-decode combine)."""
    ma, la, aa = stats_a
    mb, lb, ab = stats_b
    m = jnp.maximum(ma, mb)
    ca = jnp.exp(ma - m)
    cb = jnp.exp(mb - m)
    return m, la * ca + lb * cb, aa * ca[..., None] + ab * cb[..., None]


def seq_sharded_decode_attention(q, k_cache, v_cache, cache_valid, k_new,
                                 v_new, *, mesh, seq_axis: str, batch_axes):
    """One-token decode attention with the KV cache SEQUENCE-sharded over the
    model axis (flash-decode): each shard computes partial softmax stats over
    its cache chunk; pmax/psum merge them; the new token's self-attention is
    merged in afterwards.  Cuts per-chip cache memory by the axis size for
    GQA archs whose KV-head count cannot shard (8, 20 vs 16-way).

    q: (B,1,Hq,hd) replicated on seq_axis; k_cache/v_cache: (B,S,KV,hd)
    sharded on S; cache_valid: (B,S) bool sharded on S; k_new/v_new:
    (B,1,KV,hd) replicated.  Returns (B,1,Hq,hd).
    """
    from jax.sharding import PartitionSpec as P

    hq, hkv = q.shape[2], k_cache.shape[2]
    rep = hq // hkv
    baxes = tuple(a for a in (batch_axes or ()) if a)
    bspec = baxes if baxes else None

    def local(q_, k_, v_, valid_):
        m, l, acc = _partial_softmax_stats(q_, repeat_kv(k_, rep),
                                           repeat_kv(v_, rep), valid_)
        m_g = jax.lax.pmax(m, seq_axis)
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * corr, seq_axis)
        acc_g = jax.lax.psum(acc * corr[..., None], seq_axis)
        return m_g, l_g, acc_g

    in_specs = (P(bspec, None, None, None), P(bspec, seq_axis, None, None),
                P(bspec, seq_axis, None, None), P(bspec, seq_axis))
    out_specs = (P(bspec, None), P(bspec, None), P(bspec, None, None))
    stats_cache = shard_map(local, mesh=mesh, in_specs=in_specs,
                                out_specs=out_specs)(q, k_cache, v_cache,
                                                 cache_valid)
    # the new token always sees itself
    ones = jnp.ones(k_new.shape[:2], bool)
    stats_self = _partial_softmax_stats(q, repeat_kv(k_new, rep),
                                        repeat_kv(v_new, rep), ones)
    m, l, acc = merge_softmax_stats(stats_cache, stats_self)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out[:, None].transpose(0, 1, 2, 3).astype(q.dtype).reshape(q.shape)


def seq_sharded_cache_insert(cache_k, cache_v, k_new, v_new, pos, *, mesh,
                             seq_axis: str, batch_axes):
    """Insert one token into a sequence-sharded KV cache with ZERO
    communication: each shard locally updates iff ``pos`` lands in its chunk
    (§Perf iteration B.3 — a plain dynamic_update_slice makes GSPMD
    all-gather + rewrite the whole cache every decode step).

    cache_k/v: (B, S, KV, hd) sharded on S over seq_axis; k_new/v_new:
    (B, 1, KV, hd) replicated; pos: scalar absolute position.
    """
    from jax.sharding import PartitionSpec as P

    baxes = tuple(a for a in (batch_axes or ()) if a)
    bspec = baxes if baxes else None
    n_shards = mesh.shape[seq_axis]
    chunk = cache_k.shape[1] // n_shards

    def local(ck, cv, kn, vn):
        i = jax.lax.axis_index(seq_axis)
        lo = i * chunk
        in_range = (pos >= lo) & (pos < lo + chunk)
        lp = jnp.clip(pos - lo, 0, chunk - 1)
        cur_k = jax.lax.dynamic_slice_in_dim(ck, lp, 1, axis=1)
        cur_v = jax.lax.dynamic_slice_in_dim(cv, lp, 1, axis=1)
        wk = jnp.where(in_range, kn, cur_k)
        wv = jnp.where(in_range, vn, cur_v)
        ck = jax.lax.dynamic_update_slice_in_dim(ck, wk, lp, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, wv, lp, axis=1)
        return ck, cv

    spec = P(bspec, seq_axis, None, None)
    rspec = P(bspec, None, None, None)
    return shard_map(local, mesh=mesh,
                         in_specs=(spec, spec, rspec, rspec),
                         out_specs=(spec, spec))(
                             cache_k, cache_v, k_new, v_new)
