"""Unified model API: build step functions and input specs per architecture.

``build_model(cfg)`` returns a ``ModelApi`` whose members are pure functions —
the train loop, serving engine, and multi-pod dry-run all consume models only
through this interface.  ``input_specs`` returns ShapeDtypeStructs (no device
allocation) so ``jax.jit(...).lower(**specs)`` works for the production mesh.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.parallel.jaxcompat import shard_map

from repro.configs.base import InputShape, ModelConfig
from repro.models import inception as inc_mod
from repro.models import lstm as lstm_mod
from repro.models import transformer as tf_mod
from repro.models.transformer import ParallelCtx


def masked_nll_sum(logits, labels):
    """Summed token NLL in f32 (labels < 0 masked) — the additive per-micro
    numerator of ``cross_entropy``.  The scheduled pipeline runtime sums one
    of these per finished micro-batch and scales by the global valid-token
    count, recovering the mean the AD path computes over the whole batch."""
    logits = logits.astype(jnp.float32)
    mask = labels >= 0
    labels = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return ((logz - gold) * mask).sum()


def cross_entropy(logits, labels, n_valid_vocab: int):
    """Mean token NLL in f32; labels < 0 are masked out."""
    mask = labels >= 0
    return masked_nll_sum(logits, labels) / jnp.maximum(mask.sum(), 1)


def vocab_parallel_cross_entropy(logits, labels, n_valid_vocab: int, *,
                                 mesh, model_axis: str, batch_axes=()):
    """Cross-entropy over vocab-sharded logits WITHOUT gathering them
    (§Perf iteration D, Megatron-style).  logits: (B, S, V) sharded on V over
    ``model_axis``; labels: (B, S).  The all-gather of (B,S,V) logits
    (~1 GB/chip at llama scale) is replaced by pmax/psum of (B,S) stats.
    """
    from jax.sharding import PartitionSpec as P

    v = logits.shape[-1]
    msz = mesh.shape[model_axis]
    v_loc = v // msz
    baxes = tuple(a for a in (batch_axes or ()) if a)
    bspec = baxes if baxes else None

    def local(lg, lb):
        lg = lg.astype(jnp.float32)
        i = jax.lax.axis_index(model_axis)
        lo = i * v_loc
        # the max is a numerics-only shift: stop_gradient keeps the exact
        # logsumexp gradient while avoiding pmax's missing VJP
        m = jax.lax.stop_gradient(
            jax.lax.pmax(jax.lax.stop_gradient(lg).max(-1), model_axis))
        z = jax.lax.psum(jnp.exp(lg - m[..., None]).sum(-1), model_axis)
        logz = m + jnp.log(z)
        mask = lb >= 0
        lb = jnp.maximum(lb, 0)
        lidx = jnp.clip(lb - lo, 0, v_loc - 1)
        mine = (lb >= lo) & (lb < lo + v_loc)
        gold_loc = jnp.take_along_axis(lg, lidx[..., None], axis=-1)[..., 0]
        gold = jax.lax.psum(jnp.where(mine, gold_loc, 0.0), model_axis)
        nll = (logz - gold) * mask
        num = jax.lax.psum(nll.sum(), baxes) if baxes else nll.sum()
        den = jax.lax.psum(mask.sum(), baxes) if baxes else mask.sum()
        return num / jnp.maximum(den, 1)

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(bspec, None, model_axis), P(bspec, None)),
        out_specs=P())(logits, labels)


@dataclasses.dataclass
class ModelApi:
    cfg: ModelConfig
    init: Callable                    # key -> params
    loss_fn: Callable                 # (params, batch, pctx) -> (loss, metrics)
    prefill: Optional[Callable]       # (params, batch, pctx, capacity, window) -> (logits, cache)
    decode_fn: Optional[Callable]     # (params, cache, batch, pctx, window) -> (logits, cache)
    # (params, batch, mesh=, axis=, n_micro=, schedule=, virtual_stages=,
    # batch_axes=) -> (loss, metrics); set for the archs whose layer stack
    # the pipeline runtime can partition into stages.  This is the **ad**
    # runtime: jax.grad through pipeline_apply's forward scan.
    pipeline_loss_fn: Optional[Callable] = None
    # Same signature -> ((loss, metrics), grads); the **scheduled** runtime:
    # executes the full fwd+bwd WorkUnit table by hand
    # (parallel.pipeline.pipeline_value_and_grad), with the arch decomposed
    # into pure (params, x) -> y stage callables plus an embedding vjp'd
    # outside and a per-micro loss seeded at the emit tick.
    pipeline_value_and_grad_fn: Optional[Callable] = None

    def input_specs(self, shape: InputShape, *, reduced: bool = False) -> Dict[str, Any]:
        return make_input_specs(self.cfg, shape, reduced=reduced)

    def make_batch(self, key, shape: InputShape):
        """Materialized random batch matching input_specs (smoke tests)."""
        specs = self.input_specs(shape, reduced=True)
        out = {}
        for name, spec in specs.items():
            key, k = jax.random.split(key)
            out[name] = _random_like(k, spec)
        return out


def _random_like(key, spec):
    if isinstance(spec, dict):
        out = {}
        for n, s in spec.items():
            key, k = jax.random.split(key)
            out[n] = _random_like(k, s)
        return out
    if jnp.issubdtype(spec.dtype, jnp.integer):
        return jax.random.randint(key, spec.shape, 0, 64, dtype=spec.dtype)
    return (jax.random.normal(key, spec.shape) * 0.02).astype(spec.dtype)


# ---------------------------------------------------------------------------
# per-slot cache helpers (continuous-batching serve engine)
# ---------------------------------------------------------------------------

def make_slot_cache(cfg: ModelConfig, n_slots: int, capacity: int,
                    dtype=None):
    """A slotted KV cache for continuous batching: ``n_slots`` independent
    request slots over a LINEAR cache of ``capacity`` positions each, with
    per-slot write positions (``pos`` is (n_slots,), which is what routes
    ``decode_step`` into slot mode).  A sliding-window arch still gets full
    linear capacity — the window is enforced as an attention mask, so
    mid-flight requests at different absolute positions can share a batch."""
    if cfg.rwkv or cfg.family == "hybrid" or cfg.encoder_layers \
            or cfg.n_prefix_embeds:
        raise ValueError(
            f"slotted KV serving supports homogeneous KV-cache decoders; "
            f"{cfg.name} (family={cfg.family}) carries recurrent/cross-attn "
            f"state that has no per-position slot layout")
    dtype = jnp.dtype(cfg.dtype) if dtype is None else dtype
    cache = tf_mod.make_cache(cfg, n_slots, capacity, window=0, dtype=dtype)
    cache["pos"] = jnp.zeros((n_slots,), jnp.int32)
    return cache


def cache_extract_slot(cache, slot):
    """View one slot of a slotted cache as a batch-1 slot cache (``pos``
    (1,)) — the shape ``decode_step``'s slot-extend path takes for chunked
    prefill."""
    out = {"pos": jax.lax.dynamic_slice_in_dim(cache["pos"], slot, 1)}
    for k, v in cache.items():
        if k != "pos":
            out[k] = jax.lax.dynamic_slice_in_dim(v, slot, 1, axis=1)
    return out


def cache_insert_slot(cache, slot_cache, slot):
    """Write a batch-1 cache (``cache_extract_slot`` shape) back into
    ``slot`` of the slotted cache."""
    out = {"pos": jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], slot_cache["pos"].reshape(1).astype(cache["pos"].dtype),
        slot, axis=0)}
    for k, v in cache.items():
        if k != "pos":
            out[k] = jax.lax.dynamic_update_slice_in_dim(
                v, slot_cache[k], slot, axis=1)
    return out


def cache_evict_slot(cache, slot):
    """Free a slot: zero its KV rows and reset its position so the slot can
    be re-admitted.  (Zeroing is not strictly required — ``pos`` gates what
    attention can see — but keeps evicted state from leaking into debug
    dumps and makes reuse tests exact.)"""
    out = {"pos": jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], jnp.zeros((1,), cache["pos"].dtype), slot, axis=0)}
    for k, v in cache.items():
        if k != "pos":
            out[k] = jax.lax.dynamic_update_slice_in_dim(
                v, jnp.zeros(v.shape[:1] + (1,) + v.shape[2:], v.dtype),
                slot, axis=1)
    return out


# ---------------------------------------------------------------------------

def _decode_window(cfg, shape: InputShape) -> int:
    """Effective attention window for a decode shape: long_500k forces the
    sub-quadratic sliding-window variant on otherwise-full-attention archs
    (DESIGN.md §Arch-applicability)."""
    if cfg.rwkv:
        return 0
    if shape.seq_len > 65536:
        return cfg.sliding_window or cfg.long_context_window
    return cfg.sliding_window


def make_input_specs(cfg: ModelConfig, shape: InputShape, *, reduced: bool = False):
    """ShapeDtypeStruct stand-ins for every model input of this shape."""
    s, b = (shape.seq_len, shape.global_batch)
    if reduced:
        s, b = min(s, 128), min(b, 4)
    i32 = jnp.int32
    act = jnp.dtype(cfg.dtype)

    if cfg.family == "cnn":
        size = 128 if reduced else 299
        return {"images": jax.ShapeDtypeStruct((b, size, size, 3), act),
                "labels": jax.ShapeDtypeStruct((b,), i32)}
    if cfg.name == "gnmt":
        return {"src": jax.ShapeDtypeStruct((b, s), i32),
                "tgt": jax.ShapeDtypeStruct((b, s), i32),
                "labels": jax.ShapeDtypeStruct((b, s), i32)}
    if cfg.name == "biglstm":
        return {"tokens": jax.ShapeDtypeStruct((b, s), i32),
                "labels": jax.ShapeDtypeStruct((b, s), i32)}

    specs: Dict[str, Any] = {}
    if shape.kind == "decode":
        specs["tokens"] = jax.ShapeDtypeStruct((b, 1), i32)
        window = _decode_window(cfg, shape)
        capacity = min(shape.seq_len, window) if window else shape.seq_len
        if reduced:
            capacity = min(capacity, 64)
        cache = jax.eval_shape(
            lambda: tf_mod.make_cache(cfg, b, capacity, window=window, dtype=act))
        specs["cache"] = {k: v for k, v in cache.items()}
        if shape.kind == "decode" and cfg.encoder_layers:
            pass  # cross-attn K/V live inside the cache
        return specs

    n_text = s - (cfg.n_prefix_embeds if cfg.n_prefix_embeds else 0)
    specs["tokens"] = jax.ShapeDtypeStruct((b, max(n_text, 1)), i32)
    specs["labels"] = jax.ShapeDtypeStruct((b, max(n_text, 1)), i32)
    if cfg.n_prefix_embeds:
        npre = min(cfg.n_prefix_embeds, 8) if reduced else cfg.n_prefix_embeds
        specs["prefix"] = jax.ShapeDtypeStruct((b, npre, cfg.d_model), act)
        specs["tokens"] = jax.ShapeDtypeStruct((b, s - npre), i32)
        specs["labels"] = jax.ShapeDtypeStruct((b, s - npre), i32)
    if cfg.encoder_layers:
        specs["frames"] = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model), act)
    return specs


# ---------------------------------------------------------------------------

def supports_pipeline(cfg: ModelConfig) -> bool:
    """Archs whose layer stack the pipeline runtime can partition: BigLSTM's
    residual LSTM stack and homogeneous decoder-only transformers.  GNMT's
    encoder/decoder split and the CNN block graph need stage functions the
    GPipe runtime does not model (the planner still *costs* pipeline-MP for
    GNMT; execution falls back to the best supported plan)."""
    if cfg.name == "biglstm":
        return True
    if cfg.family == "cnn" or cfg.name == "gnmt":
        return False
    return not (cfg.encoder_layers or cfg.n_prefix_embeds or cfg.is_moe)


def pipeline_applicable(cfg: ModelConfig, n_stages: int,
                        virtual_stages: int = 1) -> bool:
    """Can this arch run as ``n_stages`` pipeline stages (each holding
    ``virtual_stages`` interleaved layer chunks) at runtime?"""
    return (supports_pipeline(cfg) and n_stages > 1
            and cfg.n_layers % (n_stages * max(virtual_stages, 1)) == 0)


def _pipeline_vag_builder(cfg, stage_key: str, make_stage_fn: Callable,
                          pre_fn: Callable, head_fn: Callable,
                          to_stacked: Callable, from_stacked: Callable):
    """Compose an arch into the scheduled pipeline runtime's three pure
    parts — ``pre_fn(outer_params, batch) -> x`` (embedding, vjp'd outside
    the pipeline), ``stage_fn(chunk_params, x) -> y`` per WorkUnit, and
    ``head_fn(outer_params, y_micro) -> logits`` feeding the per-micro NLL
    seeded at each emit tick — returning a
    ``(params, batch, ...) -> ((loss, metrics), grads)`` train-step body.

    The per-micro loss is the summed NLL scaled by the *global* inverse
    valid-token count (data-dependent but parameter-independent, so it is
    computable before the pipeline runs); summed over micro-batches it
    recovers exactly the batch-mean cross entropy the ad path computes.
    Tied embeddings fall out naturally: the embed table's head-side
    cotangent (from ``head_fn``) and embedding-side cotangent (from
    ``pre_fn``'s vjp) are summed leaf-wise.
    """
    def pipe_vag_fn(params, batch, *, mesh, axis, n_micro, schedule="gpipe",
                    virtual_stages=1, batch_axes=()):
        from repro.parallel.pipeline import (make_schedule,
                                             pipeline_value_and_grad,
                                             stack_to_stages,
                                             stages_to_stack)
        n_stages = mesh.shape[axis]
        sched = (make_schedule(schedule, n_stages, n_micro, virtual_stages)
                 if isinstance(schedule, str) else schedule)
        outer = {k: p for k, p in params.items() if k != stage_key}
        labels = batch["labels"]
        inv_count = 1.0 / jnp.maximum((labels >= 0).sum(), 1).astype(
            jnp.float32)

        x, pre_vjp = jax.vjp(lambda op: pre_fn(op, batch), outer)

        def loss_fn(lpp, y_m, lbl_m):
            return masked_nll_sum(head_fn(lpp["outer"], y_m),
                                  lbl_m) * lpp["inv_count"]

        stages = stack_to_stages(to_stacked(params[stage_key]), n_stages,
                                 sched.v)
        loss, (stage_g, lp_g, dx) = pipeline_value_and_grad(
            mesh, axis, make_stage_fn(), stages, x, loss_fn=loss_fn,
            loss_params={"outer": outer, "inv_count": inv_count},
            targets=labels, n_micro=n_micro, batch_axes=batch_axes,
            schedule=sched)
        grads = jax.tree.map(jnp.add, lp_g["outer"], pre_vjp(dx)[0])
        grads[stage_key] = from_stacked(
            stages_to_stack(stage_g, n_stages, sched.v))
        return (loss, {"loss": loss}), grads

    return pipe_vag_fn


def build_model(cfg: ModelConfig, *, rwkv_chunked: bool = True,
                remat: bool = True, capacity_factor=1.25) -> ModelApi:
    if cfg.family == "cnn":
        reduced = cfg.n_layers <= 3

        def init(key):
            return inc_mod.inception_init(key, cfg, reduced=reduced)

        def loss_fn(params, batch, pctx=None):
            logits = inc_mod.inception_forward(cfg, params, batch, reduced=reduced)
            loss = cross_entropy(logits[:, None, :], batch["labels"][:, None],
                                 cfg.vocab_size)
            return loss, {"loss": loss}

        return ModelApi(cfg, init, loss_fn, None, None)

    if cfg.name == "gnmt":
        def init(key):
            return lstm_mod.gnmt_init(key, cfg)

        def loss_fn(params, batch, pctx=None):
            logits = lstm_mod.gnmt_forward(cfg, params, batch)
            loss = cross_entropy(logits, batch["labels"], cfg.vocab_size)
            return loss, {"loss": loss}

        return ModelApi(cfg, init, loss_fn, None, None)

    if cfg.name == "biglstm":
        def init(key):
            return lstm_mod.biglstm_init(key, cfg)

        def loss_fn(params, batch, pctx=None):
            logits = lstm_mod.biglstm_forward(cfg, params, batch, pctx=pctx)
            loss = cross_entropy(logits, batch["labels"], cfg.vocab_size)
            return loss, {"loss": loss}

        def pipe_loss_fn(params, batch, *, mesh, axis, n_micro,
                         schedule="gpipe", virtual_stages=1, batch_axes=()):
            logits = lstm_mod.biglstm_forward_pipeline(
                cfg, params, batch, mesh=mesh, axis=axis, n_micro=n_micro,
                schedule=schedule, virtual_stages=virtual_stages,
                batch_axes=batch_axes)
            loss = cross_entropy(logits, batch["labels"], cfg.vocab_size)
            return loss, {"loss": loss}

        dt = jnp.dtype(cfg.dtype)
        pipe_vag_fn = _pipeline_vag_builder(
            cfg, "lstm",
            make_stage_fn=lambda: lstm_mod.biglstm_stage_fn(cfg),
            pre_fn=lambda op, b: jnp.take(op["embed"], b["tokens"],
                                          axis=0).astype(dt),
            head_fn=lambda op, y: y @ op["head"].astype(y.dtype),
            to_stacked=lstm_mod.stack_layer_params,
            from_stacked=lambda st: [
                jax.tree.map(lambda a, i=i: a[i], st)
                for i in range(cfg.n_layers)])

        return ModelApi(cfg, init, loss_fn, None, None,
                        pipeline_loss_fn=pipe_loss_fn,
                        pipeline_value_and_grad_fn=pipe_vag_fn)

    # --- transformer families ---
    def init(key):
        return tf_mod.model_init(key, cfg)

    def loss_fn(params, batch, pctx=None):
        fwd_batch = {k: v for k, v in batch.items() if k != "labels"}
        logits, aux = tf_mod.forward(cfg, params, fwd_batch, mode="train",
                                     pctx=pctx, remat=remat,
                                     rwkv_chunked=rwkv_chunked,
                                     capacity_factor=capacity_factor)
        if (pctx is not None and pctx.mesh is not None
                and pctx.model_axis is not None
                and cfg.vocab_padded % pctx.mesh.shape[pctx.model_axis] == 0):
            loss = vocab_parallel_cross_entropy(
                logits, batch["labels"], cfg.vocab_size, mesh=pctx.mesh,
                model_axis=pctx.model_axis,
                batch_axes=tuple(a for a in pctx.batch_axes if a))
        else:
            loss = cross_entropy(logits, batch["labels"], cfg.vocab_size)
        return loss + aux, {"loss": loss, "aux": aux}

    def prefill(params, batch, pctx=None, capacity: int = 0, window=None):
        fwd_batch = {k: v for k, v in batch.items() if k != "labels"}
        logits, cache, _ = tf_mod.forward(cfg, params, fwd_batch, mode="prefill",
                                          window_override=window, pctx=pctx,
                                          remat=False, cache_capacity=capacity,
                                          capacity_factor=capacity_factor)
        return logits, cache

    def decode_fn(params, cache, batch, pctx=None, window=None):
        return tf_mod.decode_step(cfg, params, cache, batch,
                                  window_override=window, pctx=pctx)

    pipe_loss_fn = pipe_vag_fn = None
    if supports_pipeline(cfg):
        def pipe_loss_fn(params, batch, *, mesh, axis, n_micro,
                         schedule="gpipe", virtual_stages=1, batch_axes=()):
            fwd_batch = {k: v for k, v in batch.items() if k != "labels"}
            logits = tf_mod.forward_pipeline(
                cfg, params, fwd_batch, mesh=mesh, axis=axis, n_micro=n_micro,
                remat=remat, rwkv_chunked=rwkv_chunked, schedule=schedule,
                virtual_stages=virtual_stages, batch_axes=batch_axes)
            loss = cross_entropy(logits, batch["labels"], cfg.vocab_size)
            return loss, {"loss": loss}

        pipe_vag_fn = _pipeline_vag_builder(
            cfg, "layers",
            make_stage_fn=lambda: tf_mod.pipeline_stage_fn(
                cfg, remat=remat, rwkv_chunked=rwkv_chunked),
            pre_fn=lambda op, b: tf_mod._embed(cfg, op, b["tokens"]),
            head_fn=lambda op, y: tf_mod._head(cfg, op, y),
            to_stacked=lambda t: t, from_stacked=lambda t: t)

    return ModelApi(cfg, init, loss_fn, prefill, decode_fn,
                    pipeline_loss_fn=pipe_loss_fn,
                    pipeline_value_and_grad_fn=pipe_vag_fn)
