"""Selective SSM (Mamba-style) head, used standalone and inside Hymba's
parallel attention+SSM hybrid block [arXiv:2411.13676].

    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * u_t
    y_t = C_t . h_t + D * u_t

with input-dependent (selective) dt, B, C; causal depthwise conv frontend; and
a gated output.  Train/prefill is a lax.scan over time; decode carries
(h, conv_buf).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def ssm_init(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    ds = cfg.ssm_state
    dt_rank = max(8, d // 16)
    ks = jax.random.split(key, 8)
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, dtype),        # x and gate z
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, di)) /
                   math.sqrt(cfg.ssm_conv)).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], di, dt_rank + 2 * ds, dtype),
        "dt_proj": dense_init(ks[3], dt_rank, di, dtype),
        "dt_bias": jnp.log(jnp.expm1(                          # softplus^-1 of dt
            jnp.exp(jax.random.uniform(ks[4], (di,),
                    minval=math.log(1e-3), maxval=math.log(1e-1))))).astype(jnp.float32),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[5], di, d, dtype),
    }


def causal_conv1d(x, w, b, init_state=None):
    """Depthwise causal conv.  x: (B,T,di); w: (K,di).  Returns (y, tail).

    ``init_state``: (B, K-1, di) carried context from a previous segment
    (decode); ``tail`` is the new (B, K-1, di) context.
    """
    k = w.shape[0]
    bsz = x.shape[0]
    if init_state is None:
        init_state = jnp.zeros((bsz, k - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([init_state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    tail = xp[:, -(k - 1):, :] if k > 1 else jnp.zeros((bsz, 0, x.shape[-1]), x.dtype)
    return y + b[None, None, :], tail


def selective_scan(u, dt, A, B, C, D, h0=None):
    """u: (b,t,di); dt: (b,t,di); A: (di,ds); B,C: (b,t,ds); D: (di,).

    Returns (y (b,t,di), h_final (b,di,ds)).  All recurrence math in f32.
    dA/dBu are formed PER STEP inside the scan: materializing the full
    (b,t,di,ds) tensors costs di*ds/(di+ds) ~ 16x more HBM (214 GB/layer for
    hymba prefill_32k) and defeats GSPMD's di-sharding of the recurrence
    (§Perf iteration A.3).
    """
    b, t, di = u.shape
    ds = A.shape[1]
    f32 = jnp.float32

    def step(h, xs):
        dt_t, B_t, C_t, u_t = xs                  # (b,di), (b,ds), (b,ds), (b,di)
        dA_t = jnp.exp(dt_t[..., None] * A[None])             # (b,di,ds)
        dBu_t = (dt_t * u_t)[..., None] * B_t[:, None, :]
        h = dA_t * h + dBu_t
        y = jnp.einsum("bds,bs->bd", h, C_t)
        return h, y

    if h0 is None:
        h0 = jnp.zeros((b, di, ds), f32)
    xs = (dt.astype(f32).transpose(1, 0, 2), B.astype(f32).transpose(1, 0, 2),
          C.astype(f32).transpose(1, 0, 2), u.astype(f32).transpose(1, 0, 2))
    h, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2) + D[None, None] * u.astype(f32)
    return y.astype(u.dtype), h


def ssm_apply(p, x, cfg, state=None):
    """x: (B,T,d).  state: None or dict(h, conv).  Returns (out, new_state)."""
    di = cfg.ssm_expand * cfg.d_model
    dt_rank = p["dt_proj"].shape[0]
    ds = cfg.ssm_state
    xz = x @ p["in_proj"].astype(x.dtype)
    u, z = jnp.split(xz, 2, axis=-1)
    conv_state = state["conv"] if state is not None else None
    u, conv_tail = causal_conv1d(u, p["conv_w"].astype(x.dtype),
                                 p["conv_b"].astype(x.dtype), conv_state)
    u = jax.nn.silu(u)
    proj = u @ p["x_proj"].astype(x.dtype)
    dt_r, B, C = jnp.split(proj, [dt_rank, dt_rank + ds], axis=-1)
    dt = jax.nn.softplus(
        (dt_r @ p["dt_proj"].astype(x.dtype)).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    h0 = state["h"] if state is not None else None
    y, h = selective_scan(u, dt, A, B, C, p["D"], h0)
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(x.dtype)
    return out, {"h": h, "conv": conv_tail}
