"""Inception-V3 in JAX (Szegedy et al. 2015) — the paper's branchy-CNN model.

Faithful block structure (stem, 3x InceptionA, B-reduction, 4x InceptionC,
D-reduction, 2x InceptionE, pool, fc).  The parallel branches inside each
block are exactly the DFG parallelism DLPlacer exploits (§6 of the paper);
``inception_dfg()`` exports the block-level dataflow graph with analytically
estimated per-op FLOPs/bytes as DLPlacer input — reproducing the paper's
Inception-V3 case study.
"""
from __future__ import annotations

import math
from typing import List, Tuple

import jax
import jax.numpy as jnp


def conv_init(key, kh, kw, cin, cout, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(kh * kw * cin)
    w = jax.random.normal(key, (kh, kw, cin, cout), jnp.float32) * scale
    return {"w": w.astype(dtype), "scale": jnp.ones((cout,), jnp.float32),
            "bias": jnp.zeros((cout,), jnp.float32)}


def conv_bn(p, x, stride=1, padding="SAME"):
    y = jax.lax.conv_general_dilated(
        x, p["w"].astype(x.dtype), (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    # inference-style folded batch-norm (scale/bias) + relu
    return jax.nn.relu(y * p["scale"].astype(x.dtype) + p["bias"].astype(x.dtype))


def pool(x, kind, k=3, stride=1, padding="SAME"):
    if kind == "max":
        return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                     (1, k, k, 1), (1, stride, stride, 1), padding)
    s = jax.lax.reduce_window(x, 0.0, jax.lax.add,
                              (1, k, k, 1), (1, stride, stride, 1), padding)
    n = jax.lax.reduce_window(jnp.ones_like(x), 0.0, jax.lax.add,
                              (1, k, k, 1), (1, stride, stride, 1), padding)
    return s / n


# Block specs: list of branches; each branch = list of (kh, kw, cout, stride).
def _inception_a(cin, pool_ch):
    return [[(1, 1, 64, 1)],
            [(1, 1, 48, 1), (5, 5, 64, 1)],
            [(1, 1, 64, 1), (3, 3, 96, 1), (3, 3, 96, 1)],
            [("avgpool",), (1, 1, pool_ch, 1)]]


def _inception_b(cin):  # grid reduction 35->17
    return [[(3, 3, 384, 2)],
            [(1, 1, 64, 1), (3, 3, 96, 1), (3, 3, 96, 2)],
            [("maxpool2",)]]


def _inception_c(cin, c7):
    return [[(1, 1, 192, 1)],
            [(1, 1, c7, 1), (1, 7, c7, 1), (7, 1, 192, 1)],
            [(1, 1, c7, 1), (7, 1, c7, 1), (1, 7, c7, 1), (7, 1, c7, 1), (1, 7, 192, 1)],
            [("avgpool",), (1, 1, 192, 1)]]


def _inception_d(cin):  # grid reduction 17->8
    return [[(1, 1, 192, 1), (3, 3, 320, 2)],
            [(1, 1, 192, 1), (1, 7, 192, 1), (7, 1, 192, 1), (3, 3, 192, 2)],
            [("maxpool2",)]]


def _inception_e(cin):
    return [[(1, 1, 320, 1)],
            [(1, 1, 384, 1), (1, 3, 384, 1)],   # (+ 3x1 sibling merged below)
            [(1, 1, 384, 1), (3, 1, 384, 1)],
            [(1, 1, 448, 1), (3, 3, 384, 1), (1, 3, 384, 1)],
            [(1, 1, 448, 1), (3, 3, 384, 1), (3, 1, 384, 1)],
            [("avgpool",), (1, 1, 192, 1)]]


def _blocks(reduced: bool):
    if reduced:
        return [("a", _inception_a(192, 32)), ("b", _inception_b(256)),
                ("e", _inception_e(768))]
    return [
        ("a", _inception_a(192, 32)), ("a", _inception_a(256, 64)),
        ("a", _inception_a(288, 64)),
        ("b", _inception_b(288)),
        ("c", _inception_c(768, 128)), ("c", _inception_c(768, 160)),
        ("c", _inception_c(768, 160)), ("c", _inception_c(768, 192)),
        ("d", _inception_d(768)),
        ("e", _inception_e(1280)), ("e", _inception_e(2048)),
    ]


def inception_init(key, cfg, image_size: int = 299, reduced: bool = False):
    dtype = jnp.dtype(cfg.param_dtype)
    ks = iter(jax.random.split(key, 256))
    stem = [conv_init(next(ks), 3, 3, 3, 32, dtype),
            conv_init(next(ks), 3, 3, 32, 32, dtype),
            conv_init(next(ks), 3, 3, 32, 64, dtype),
            conv_init(next(ks), 1, 1, 64, 80, dtype),
            conv_init(next(ks), 3, 3, 80, 192, dtype)]
    blocks = []
    cin = 192
    for kind, spec in _blocks(reduced):
        branches = []
        for branch in spec:
            ops, c = [], cin
            for op in branch:
                if isinstance(op[0], str):
                    continue  # pools are parameter-free; forward reads the spec
                kh, kw, cout, stride = op
                ops.append(conv_init(next(ks), kh, kw, c, cout, dtype))
                c = cout
            branches.append(ops)
        blocks.append(branches)
        cin = _out_channels(spec, cin)
    head = {"fc": (jax.random.normal(next(ks), (cin, cfg.vocab_size)) * 0.01
                   ).astype(dtype)}
    return {"stem": stem, "blocks": blocks, "head": head}


def _out_channels(spec, cin):
    total = 0
    for branch in spec:
        last_conv = None
        for op in branch:
            if not isinstance(op[0], str):
                last_conv = op
        if last_conv is None:  # pure pool branch keeps cin
            total += cin
        else:
            total += last_conv[2]
    return total


def inception_forward(cfg, params, batch, reduced: bool = False):
    """batch: dict(images (B,H,W,3)).  Returns logits (B, n_classes)."""
    x = batch["images"].astype(jnp.dtype(cfg.dtype))
    p = params["stem"]
    x = conv_bn(p[0], x, stride=2, padding="VALID")
    x = conv_bn(p[1], x, padding="VALID")
    x = conv_bn(p[2], x)
    x = pool(x, "max", 3, 2, "VALID")
    x = conv_bn(p[3], x, padding="VALID")
    x = conv_bn(p[4], x, padding="VALID")
    x = pool(x, "max", 3, 2, "VALID")
    specs = _blocks(reduced)
    for (kind, spec), branches in zip(specs, params["blocks"]):
        outs = []
        for branch_spec, branch in zip(spec, branches):
            y = x
            conv_it = iter(branch)
            for op_spec in branch_spec:
                if isinstance(op_spec[0], str):
                    if op_spec[0] == "avgpool":
                        y = pool(y, "avg", 3, 1, "SAME")
                    else:  # maxpool2: grid reduction
                        y = pool(y, "max", 3, 2, "VALID")
                else:
                    stride = op_spec[3]
                    y = conv_bn(next(conv_it), y, stride=stride,
                                padding="VALID" if stride == 2 else "SAME")
            outs.append(y)
        x = jnp.concatenate(outs, axis=-1)
    x = jnp.mean(x, axis=(1, 2))
    return x @ params["head"]["fc"].astype(x.dtype)


# ---------------------------------------------------------------------------
# DFG export for DLPlacer (the paper's §6 case study)
# ---------------------------------------------------------------------------

def inception_dfg(image_size: int = 299, batch: int = 32):
    """Block-level DFG with analytic per-op costs — DLPlacer input.

    Returns (nodes, edges): nodes = {name: dict(flops, bytes_out, mem)};
    edges = [(src, dst)].  Grid sizes follow the standard V3 schedule
    (299 -> 35x35x288 -> 17x17x768 -> 8x8x2048).
    """
    nodes, edges = {}, []

    def add(name, flops, bytes_out, deps):
        nodes[name] = {"flops": float(flops), "bytes_out": float(bytes_out),
                       "mem": float(bytes_out)}
        for d in deps:
            edges.append((d, name))

    add("stem", 2 * 3.3e9 * batch / 32, batch * 35 * 35 * 192 * 4, [])
    prev = "stem"
    grid = {"a": (35, 288), "b": (17, 768), "c": (17, 768), "d": (8, 1280),
            "e": (8, 2048)}
    for bi, (kind, spec) in enumerate(_blocks(reduced=False)):
        g, cout_total = grid[kind]
        branch_names = []
        for j, branch in enumerate(spec):
            flops = 0.0
            cin = 288 if kind == "a" else (768 if kind in "bc" else
                                           (1280 if kind == "d" else 2048))
            c = cin
            for op in branch:
                if isinstance(op[0], str):
                    continue
                kh, kw, cout, stride = op
                flops += 2 * kh * kw * c * cout * g * g * batch
                c = cout
            name = f"blk{bi}_{kind}{j}"
            add(name, flops, batch * g * g * c * 4, [prev])
            branch_names.append(name)
        concat = f"blk{bi}_concat"
        add(concat, batch * g * g * cout_total,
            batch * g * g * cout_total * 4, branch_names)
        prev = concat
    add("head", 2 * 2048 * 1000 * batch, batch * 1000 * 4, [prev])
    return nodes, edges
