"""RWKV-6 ("Finch") blocks — attention-free, data-dependent decay
[arXiv:2404.05892].

Time-mix recurrence per head (key dim = value dim = head_dim):

    S_t = diag(w_t) @ S_{t-1} + k_t^T v_t
    o_t = r_t @ (S_{t-1} + diag(u) k_t^T v_t)

with data-dependent decay w_t = exp(-exp(w0 + LoRA(x_t))) in (0, 1), receptance
r, key k, value v from token-shifted projections, and bonus u for the current
token.  Sequential form is a lax.scan; the chunked-parallel form (processing C
tokens per scan step with intra-chunk matmuls — the MXU-friendly variant) is
``wkv_chunked`` and is bit-validated against the scan in tests.  Decode carries
(S, last_x) state.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import analysis_unroll, dense_init, rms_norm


def rwkv_layer_init(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    hd = cfg.head_dim or 64
    n_heads = d // hd
    lora = max(32, d // 64)
    ks = jax.random.split(key, 12)
    return {
        "ln1": jnp.ones((d,), jnp.float32),
        "ln2": jnp.ones((d,), jnp.float32),
        "tm": {  # time mix
            "mu_r": jnp.full((d,), 0.5, jnp.float32),
            "mu_k": jnp.full((d,), 0.5, jnp.float32),
            "mu_v": jnp.full((d,), 0.5, jnp.float32),
            "mu_w": jnp.full((d,), 0.5, jnp.float32),
            "mu_g": jnp.full((d,), 0.5, jnp.float32),
            "wr": dense_init(ks[0], d, d, dtype),
            "wk": dense_init(ks[1], d, d, dtype),
            "wv": dense_init(ks[2], d, d, dtype),
            "wg": dense_init(ks[3], d, d, dtype),
            "wo": dense_init(ks[4], d, d, dtype),
            # decay: w0 + tanh(x @ a1) @ a2 (LoRA)
            "w0": jnp.linspace(-6.0, -1.0, d, dtype=jnp.float32).reshape(n_heads, hd).reshape(-1),
            "wa1": dense_init(ks[5], d, lora, jnp.float32),
            "wa2": (jax.random.normal(ks[6], (lora, d)) * 0.01).astype(jnp.float32),
            "u": (jax.random.normal(ks[7], (n_heads, hd)) * 0.1).astype(jnp.float32),
            "ln_x": jnp.ones((d,), jnp.float32),
        },
        "cm": {  # channel mix
            "mu_k": jnp.full((d,), 0.5, jnp.float32),
            "mu_r": jnp.full((d,), 0.5, jnp.float32),
            "wk": dense_init(ks[8], d, cfg.d_ff, dtype),
            "wv": dense_init(ks[9], cfg.d_ff, d, dtype),
            "wr": dense_init(ks[10], d, d, dtype),
        },
    }


def _token_shift(x, last_x):
    """x: (B,T,d); last_x: (B,d) from the previous step/segment."""
    prev = jnp.concatenate([last_x[:, None, :], x[:, :-1, :]], axis=1)
    return prev, x[:, -1, :]


def wkv_scan(r, k, v, w, u):
    """Sequential WKV.  r,k,v,w: (B,T,H,hd); u: (H,hd) -> (out (B,T,H,hd), S).

    All math in f32; S: (B,H,hd,hd) with layout S[key_dim, value_dim].
    """
    b, t, h, hd = r.shape

    def step(S, xs):
        r_t, k_t, v_t, w_t = xs          # (B,H,hd)
        kv = k_t[..., :, None] * v_t[..., None, :]          # (B,H,hdk,hdv)
        att = S + u[None, :, :, None] * kv                  # bonus for current
        o = jnp.einsum("bhk,bhkv->bhv", r_t, att)
        S = w_t[..., :, None] * S + kv
        return S, o

    xs = tuple(a.astype(jnp.float32).transpose(1, 0, 2, 3) for a in (r, k, v, w))
    S0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    S, out = jax.lax.scan(step, S0, xs)
    return out.transpose(1, 0, 2, 3), S


def wkv_chunked(r, k, v, w, u, chunk: int = 64):
    """Chunked-parallel WKV: identical math, O(T/chunk) sequential steps.

    Within a chunk, cross-token attention uses decay-product matrices so the
    inner work is dense matmuls (MXU-aligned); the recurrent state advances
    once per chunk.
    """
    b, t, h, hd = r.shape
    assert t % chunk == 0, (t, chunk)
    n = t // chunk
    f32 = jnp.float32
    rc = r.astype(f32).reshape(b, n, chunk, h, hd).transpose(1, 0, 3, 2, 4)
    kc = k.astype(f32).reshape(b, n, chunk, h, hd).transpose(1, 0, 3, 2, 4)
    vc = v.astype(f32).reshape(b, n, chunk, h, hd).transpose(1, 0, 3, 2, 4)
    wc = w.astype(f32).reshape(b, n, chunk, h, hd).transpose(1, 0, 3, 2, 4)
    logw = jnp.log(jnp.maximum(wc, 1e-38))                   # (n,b,h,c,hd)
    cum = jnp.cumsum(logw, axis=3)                           # inclusive
    cum_excl = cum - logw

    def step(S, xs):
        rc_, kc_, vc_, cum_, cume_, w_ = xs                  # (b,h,c,hd)
        total = cum_[:, :, -1:, :]                           # (b,h,1,hd)
        # inter-chunk: r_i decayed-from-state
        r_dec = rc_ * jnp.exp(cume_)                         # (b,h,c,hd)
        inter = jnp.einsum("bhck,bhkv->bhcv", r_dec, S)
        # intra-chunk pairwise: scores[i,j] = sum_k r_ik k_jk exp(cume_i - cum_j)
        # for j < i, computed as (r*exp(cume)) @ (k*exp(-cum))^T.  Note
        # cume_i - cum_j = sum of logw over (j, i-1] <= 0 whenever j < i, so the
        # masked entries are the only ones where exp() can blow up — the
        # per-factor split is still safe in f32 for |cum| < ~80; decays are
        # exp(-exp(.)) <= 1 so cum is monotonically decreasing and bounded by
        # the chunk size.
        a = rc_ * jnp.exp(cume_)
        bmat = kc_ * jnp.exp(-cum_)
        scores = jnp.einsum("bhck,bhdk->bhcd", a, bmat)
        ii = jnp.arange(chunk)
        causal = (ii[:, None] > ii[None, :]).astype(f32)
        scores = scores * causal[None, None]
        diag = jnp.einsum("bhck,bhck->bhc", rc_ * u[None, :, None, :], kc_)
        intra = jnp.einsum("bhcd,bhdv->bhcv", scores, vc_) + diag[..., None] * vc_
        out = inter + intra
        # advance state: S' = diag(exp(total)) S + sum_j exp(total - cum_j) k_j v_j^T
        kw = kc_ * jnp.exp(total - cum_)
        S = jnp.exp(total).transpose(0, 1, 3, 2) * S + jnp.einsum(
            "bhck,bhcv->bhkv", kw, vc_)
        return S, out

    S0 = jnp.zeros((b, h, hd, hd), f32)
    S, out = jax.lax.scan(step, S0, (rc, kc, vc, cum, cum_excl, wc),
                          unroll=n if analysis_unroll() else 1)
    out = out.transpose(1, 0, 3, 2, 4).reshape(b, t, h, hd)
    return out, S


def rwkv_time_mix(p, x, last_x, S, cfg, chunked: bool = False):
    """x: (B,T,d).  Returns (out, new_last_x, new_S)."""
    b, t, d = x.shape
    hd = cfg.head_dim or 64
    h = d // hd
    prev, new_last = _token_shift(x, last_x)

    def mix(mu):
        return x + (prev - x) * mu.astype(x.dtype)

    r = (mix(p["mu_r"]) @ p["wr"].astype(x.dtype)).reshape(b, t, h, hd)
    k = (mix(p["mu_k"]) @ p["wk"].astype(x.dtype)).reshape(b, t, h, hd)
    v = (mix(p["mu_v"]) @ p["wv"].astype(x.dtype)).reshape(b, t, h, hd)
    g = jax.nn.silu(mix(p["mu_g"]) @ p["wg"].astype(x.dtype))
    xw = mix(p["mu_w"]).astype(jnp.float32)
    dec = p["w0"] + jnp.tanh(xw @ p["wa1"]) @ p["wa2"]
    w = jnp.exp(-jnp.exp(dec)).reshape(b, t, h, hd)          # (0,1)
    # analysis artifacts force the chunked-parallel form: its unrolled HLO
    # counts the full recurrence, and it is also the MXU-friendly production
    # path (validated against the sequential scan in tests)
    chunk = 256 if analysis_unroll() else 64
    use_chunked = ((chunked or analysis_unroll()) and t % chunk == 0
                   and t > chunk and S is None)
    if use_chunked:
        o, S_new = wkv_chunked(r, k, v, w, p["u"], chunk=chunk)
    else:
        o, S_new = _wkv_with_init(r, k, v, w, p["u"], S)
    o = o.reshape(b, t, d).astype(x.dtype)
    o = rms_norm(o, p["ln_x"], 1e-5) * g
    return o @ p["wo"].astype(x.dtype), new_last, S_new


def _wkv_with_init(r, k, v, w, u, S0):
    b, t, h, hd = r.shape
    if S0 is None:
        S0 = jnp.zeros((b, h, hd, hd), jnp.float32)

    def step(S, xs):
        r_t, k_t, v_t, w_t = xs
        kv = k_t[..., :, None] * v_t[..., None, :]
        o = jnp.einsum("bhk,bhkv->bhv", r_t, S + u[None, :, :, None] * kv)
        return w_t[..., :, None] * S + kv, o

    xs = tuple(a.astype(jnp.float32).transpose(1, 0, 2, 3) for a in (r, k, v, w))
    S, out = jax.lax.scan(step, S0, xs)
    return out.transpose(1, 0, 2, 3), S


def rwkv_channel_mix(p, x, last_x):
    prev, new_last = _token_shift(x, last_x)
    xk = x + (prev - x) * p["mu_k"].astype(x.dtype)
    xr = x + (prev - x) * p["mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(xk @ p["wk"].astype(x.dtype)))
    r = jax.nn.sigmoid(xr @ p["wr"].astype(x.dtype))
    return r * (k @ p["wv"].astype(x.dtype)), new_last
