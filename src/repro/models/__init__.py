"""Model zoo: pure-JAX definitions for every assigned architecture family."""
from repro.models.api import ModelApi, build_model, cross_entropy, make_input_specs

__all__ = ["ModelApi", "build_model", "cross_entropy", "make_input_specs"]
