"""The paper's RNN evaluation models in JAX: GNMT (4-layer LSTM enc-dec with
attention, Wu et al. 2016) and BigLSTM (Jozefowicz et al. 2016: embedding 1024,
2 LSTM layers hidden 8192 with 1024 projection, big softmax).

These are the models the paper pipelines (Table 1: GNMT 1.15x, BigLSTM 1.22x
2-way MP) — the pipeline runtime in ``repro.parallel.pipeline`` partitions
their layer stacks into stages.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, embed_init
from repro.parallel.pipeline import pipeline_apply, stack_to_stages


def stack_layer_params(layer_list):
    """Homogeneous per-layer param dicts -> one stacked (L, ...) pytree, the
    layout ``parallel.pipeline.stack_to_stages`` partitions into stages.

    Stacks via dynamic-update-slice rather than ``jnp.stack``: on jax 0.4.x
    a ``concatenate`` feeding a ``shard_map`` operand miscompiles under the
    SPMD partitioner when the mesh has an axis the in_specs do not mention
    (the dp axis of a dp x stages mesh) — the assembled output gets an
    erroneous cross-replica reduction.  DUS takes the same layout without
    tripping that path; see test_pipeline_dp_stages_grads_equal_pure_dp.
    """
    def stack(*xs):
        out = jnp.zeros((len(xs),) + xs[0].shape, xs[0].dtype)
        for i, x in enumerate(xs):
            out = jax.lax.dynamic_update_slice_in_dim(out, x[None], i, 0)
        return out

    return jax.tree.map(stack, *layer_list)


def lstm_cell_init(key, d_in: int, d_h: int, d_proj: int = 0, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {
        "wx": dense_init(ks[0], d_in, 4 * d_h, dtype),
        "wh": dense_init(ks[1], d_proj or d_h, 4 * d_h, dtype),
        "b": jnp.zeros((4 * d_h,), jnp.float32),
    }
    if d_proj:
        p["wp"] = dense_init(ks[2], d_h, d_proj, dtype)
    return p


def lstm_cell(p, x, state):
    """x: (B, d_in); state: (h, c).  Returns (new_state, output)."""
    h, c = state
    gates = x @ p["wx"].astype(x.dtype) + h @ p["wh"].astype(x.dtype) \
        + p["b"].astype(x.dtype)
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    out = jax.nn.sigmoid(o) * jnp.tanh(c)
    if "wp" in p:
        out = out @ p["wp"].astype(x.dtype)
    return (out, c), out


def lstm_layer(p, xs, state=None):
    """xs: (B, T, d_in) -> (B, T, d_out); scan over time."""
    b = xs.shape[0]
    d_h = p["wx"].shape[1] // 4
    d_out = p["wp"].shape[1] if "wp" in p else d_h
    if state is None:
        state = (jnp.zeros((b, d_out), xs.dtype), jnp.zeros((b, d_h), xs.dtype))

    def step(st, x):
        return lstm_cell(p, x, st)

    state, ys = jax.lax.scan(step, state, xs.transpose(1, 0, 2))
    return ys.transpose(1, 0, 2), state


def lstm_layer_overlapped(p, xs, *, mesh, axis: str, batch_axes=(),
                          chunks: int = 1):
    """Megatron tensor-MP LSTM layer on the overlap-scheduled collective
    rings (``parallel.collectives``): the time-parallel input projection
    ``x @ wx`` — the layer's dominant matmul — rides an
    ``all_gather_matmul`` ring over the TIME dim with gate-major hidden
    sharding (each shard owns a dh/m slice of every gate, so the cell
    nonlinearities stay shard-local); the recurrence keeps h replicated
    (``wh`` column-sharded, no comm per step) and the cell state c sharded.
    The per-step output projection (``wp``, row-parallel) psums — the
    recurrent dependence serializes it, which is exactly the exposed-MP-comm
    term the paper measures for the RNN models; cells without a projection
    all-gather their sharded hidden instead.  xs: (B, T, d_in) with
    T % axis_size == 0.  Returns (ys, (h, c)) like ``lstm_layer``."""
    from jax.sharding import PartitionSpec as P

    from repro.parallel.collectives import all_gather_matmul
    from repro.parallel.jaxcompat import shard_map

    m = mesh.shape[axis]
    b, t, d_in = xs.shape
    d_h = p["wx"].shape[1] // 4
    have_wp = "wp" in p
    d_out = p["wp"].shape[1] if have_wp else d_h
    dhm = d_h // m
    baxes = tuple(a for a in batch_axes if a)
    dp = 1
    for a in baxes:
        dp *= mesh.shape[a]
    bspec = baxes if (baxes and dp > 1 and b % dp == 0) else None

    # gate-major view: (d, 4*dh) -> (d, 4, dh) so the model axis shards the
    # hidden dim of every gate instead of splitting whole gates apart
    wx3 = p["wx"].reshape(d_in, 4, d_h)
    wh3 = p["wh"].reshape(d_out, 4, d_h)
    b2 = p["b"].reshape(4, d_h)
    h0 = jnp.zeros((b, d_out), xs.dtype)
    c0 = jnp.zeros((b, d_h), xs.dtype)

    def local(wx_l, wh_l, b_l, wp_l, xs_l, h0_l, c0_l):
        dt = xs_l.dtype
        gates_x = all_gather_matmul(
            xs_l, wx_l.reshape(d_in, 4 * dhm).astype(dt),
            axis=axis, axis_size=m, chunks=chunks)          # (b, T, 4*dh/m)
        wh_f = wh_l.reshape(d_out, 4 * dhm).astype(dt)
        b_f = b_l.reshape(4 * dhm).astype(dt)

        def step(st, gx):
            h, c = st
            gates = gx + h @ wh_f + b_f
            i, f, g, o = jnp.split(gates, 4, axis=-1)
            c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
            out = jax.nn.sigmoid(o) * jnp.tanh(c)           # (b, dh/m)
            if wp_l is not None:
                h = jax.lax.psum(out @ wp_l.astype(dt), axis)
            else:
                h = jax.lax.all_gather(out, axis, axis=-1, tiled=True)
            return (h, c), h

        (h, c), ys = jax.lax.scan(step, (h0_l, c0_l),
                                  gates_x.transpose(1, 0, 2))
        return ys.transpose(1, 0, 2), h, c

    gate_spec = P(None, None, axis)
    specs = [gate_spec, gate_spec, P(None, axis)]
    args = [wx3, wh3, b2]
    if have_wp:
        specs.append(P(axis, None))
        args.append(p["wp"])
        fn = local
    else:
        specs.append(P())
        args.append(jnp.zeros((), xs.dtype))

        def fn(wx_l, wh_l, b_l, _unused, xs_l, h0_l, c0_l):
            return local(wx_l, wh_l, b_l, None, xs_l, h0_l, c0_l)

    specs += [P(bspec, axis, None), P(bspec, None), P(bspec, axis)]
    args += [xs, h0, c0]
    ys, h, c = shard_map(
        fn, mesh=mesh, in_specs=tuple(specs),
        out_specs=(P(bspec, None, None), P(bspec, None), P(bspec, axis)))(
            *args)
    return ys, (h, c)


def lstm_overlapped_ok(cfg, pctx, t: int) -> bool:
    """Gate for the overlapped tensor-MP LSTM path: a real model axis, the
    hidden dim divisible by it (gate-major sharding), and the time dim
    divisible (the input projection rides a time-dim gather ring)."""
    if (pctx is None or getattr(pctx, "comm_runtime", "gspmd") != "overlapped"
            or pctx.mesh is None or pctx.model_axis is None):
        return False
    m = pctx.mesh.shape[pctx.model_axis]
    if m <= 1:
        return False
    chunks = max(getattr(pctx, "comm_chunks", 1), 1)
    return (cfg.d_ff % m == 0 and t % m == 0 and (t // m) % chunks == 0)


# ---------------------------------------------------------------------------
# GNMT
# ---------------------------------------------------------------------------

def gnmt_init(key, cfg):
    dtype = jnp.dtype(cfg.param_dtype)
    d, v, n = cfg.d_model, cfg.vocab_padded, cfg.n_layers
    ks = jax.random.split(key, 4 + 2 * n)
    params = {
        "src_embed": embed_init(ks[0], v, d, dtype),
        "tgt_embed": embed_init(ks[1], v, d, dtype),
        "enc": [lstm_cell_init(ks[2 + i], d if i == 0 else d, d, 0, dtype)
                for i in range(n)],
        "dec": [lstm_cell_init(ks[2 + n + i], (2 * d) if i == 0 else d, d, 0, dtype)
                for i in range(n)],
        "attn_q": dense_init(ks[2 + 2 * n], d, d, dtype),
        "head": dense_init(ks[3 + 2 * n], d, v, dtype),
    }
    return params


def gnmt_forward(cfg, params, batch):
    """batch: dict(src (B,S), tgt (B,T)).  Returns logits (B,T,V)."""
    dt = jnp.dtype(cfg.dtype)
    src = jnp.take(params["src_embed"], batch["src"], axis=0).astype(dt)
    x = src
    for i, lp in enumerate(params["enc"]):
        y, _ = lstm_layer(lp, x)
        x = y if i == 0 else x + y                       # residual from layer 2
    enc_out = x                                          # (B, S, d)
    tgt = jnp.take(params["tgt_embed"], batch["tgt"], axis=0).astype(dt)
    # Luong attention over encoder states from the first decoder layer's
    # output; attention context fed to subsequent layers (GNMT-style).
    y0, _ = lstm_layer(params["dec"][0],
                       jnp.concatenate([tgt, jnp.zeros_like(tgt)], -1))
    q = y0 @ params["attn_q"].astype(dt)
    scores = jnp.einsum("btd,bsd->bts", q, enc_out) / math.sqrt(cfg.d_model)
    ctx = jnp.einsum("bts,bsd->btd", jax.nn.softmax(scores, -1), enc_out)
    x = y0 + ctx
    for lp in params["dec"][1:]:
        y, _ = lstm_layer(lp, x)
        x = x + y
    return x @ params["head"].astype(dt)


# ---------------------------------------------------------------------------
# BigLSTM
# ---------------------------------------------------------------------------

def biglstm_init(key, cfg):
    dtype = jnp.dtype(cfg.param_dtype)
    d, v, dh = cfg.d_model, cfg.vocab_padded, cfg.d_ff
    ks = jax.random.split(key, 2 + cfg.n_layers)
    return {
        "embed": embed_init(ks[0], v, d, dtype),
        "lstm": [lstm_cell_init(ks[1 + i], d, dh, d, dtype)
                 for i in range(cfg.n_layers)],
        "head": dense_init(ks[1 + cfg.n_layers], d, v, dtype),
    }


def biglstm_forward(cfg, params, batch, pctx=None):
    dt = jnp.dtype(cfg.dtype)
    x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(dt)
    overlapped = lstm_overlapped_ok(cfg, pctx, batch["tokens"].shape[1])
    if (not overlapped and pctx is not None
            and getattr(pctx, "comm_runtime", "gspmd") == "overlapped"
            and pctx.mesh is not None and pctx.model_axis is not None
            and pctx.mesh.shape[pctx.model_axis] > 1):
        import warnings
        warnings.warn(
            f"[collectives] biglstm: comm_runtime='overlapped' requested but "
            f"the overlapped LSTM layer cannot engage (needs hidden "
            f"({cfg.d_ff}) and seq ({batch['tokens'].shape[1]}) divisible "
            f"by the model axis and (seq/mp) % comm_chunks == 0); falling "
            f"back to GSPMD's monolithic collectives", stacklevel=2)
    for lp in params["lstm"]:
        if overlapped:
            y, _ = lstm_layer_overlapped(
                lp, x, mesh=pctx.mesh, axis=pctx.model_axis,
                batch_axes=tuple(a for a in pctx.batch_axes if a),
                chunks=max(pctx.comm_chunks, 1))
        else:
            y, _ = lstm_layer(lp, x)
        x = x + y
    return x @ params["head"].astype(dt)


def biglstm_stage_fn(cfg):
    """One pipeline chunk of BigLSTM's residual LSTM stack as a pure
    shape-preserving ``(chunk_params, x) -> y`` callable — the unit the
    hand-scheduled runtime ``jax.vjp``'s per WorkUnit."""

    def stage_fn(sp, x):
        def body(x, lp):
            y, _ = lstm_layer(lp, x)
            return x + y, None

        x, _ = jax.lax.scan(body, x, sp)
        return x

    return stage_fn


def biglstm_forward_pipeline(cfg, params, batch, *, mesh, axis: str,
                             n_micro: int, schedule: str = "gpipe",
                             virtual_stages: int = 1, batch_axes=()):
    """BigLSTM forward with the residual LSTM stack partitioned into
    pipeline stages over mesh ``axis`` — the paper's §4.4 MP implementation
    for the RNN models, streaming ``n_micro`` micro-batches through the
    stages under the requested ``schedule`` while ``batch_axes`` carries the
    data parallelism.  Bit-equal (fp32) to ``biglstm_forward``;
    embed/softmax stay replicated."""
    dt = jnp.dtype(cfg.dtype)
    x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(dt)
    n_stages = mesh.shape[axis]
    stages = stack_to_stages(stack_layer_params(params["lstm"]), n_stages,
                             virtual_stages)
    x = pipeline_apply(mesh, axis, biglstm_stage_fn(cfg), stages, x,
                       n_micro=n_micro, schedule=schedule,
                       virtual_stages=virtual_stages, batch_axes=batch_axes)
    return x @ params["head"].astype(dt)
