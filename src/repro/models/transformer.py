"""Unified decoder stack covering the dense / moe / ssm(rwkv) / hybrid / vlm /
audio families.

Layers are *stacked* (leading L dim on every leaf) and applied with
``jax.lax.scan`` so the HLO stays one-layer-sized for the 61/96-layer archs.
Three entry points share the block code:

    forward_train   (B,S) tokens -> (B,S,V) logits           [train / prefill-bench]
    prefill         also builds the KV/state cache
    decode_step     one token against the cache               [decode shapes]

``ParallelCtx`` carries mesh info so the MoE block can run its expert-parallel
shard_map; everything else distributes via GSPMD shardings assigned by
``repro.parallel.sharding``.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.parallel.jaxcompat import shard_map
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    """Mesh context handed to blocks that need manual collectives (MoE EP)."""

    mesh: Any = None
    batch_axes: tuple = ("data",)     # mesh axes the batch dim is sharded over
    model_axis: Optional[str] = None  # None => mp=1, no shard_map
    moe_ff_axes: tuple = ()           # decode: 2D expert sharding (§Perf B)
    # tensor-MP collective runtime: "gspmd" lets the partitioner insert
    # monolithic all-reduces around the Megatron matmuls; "overlapped" routes
    # them through parallel.collectives' chunked ppermute rings
    comm_runtime: str = "gspmd"
    comm_chunks: int = 1              # ring chunks per shard (overlapped)
    # context parallelism: the mesh axis carrying the sequence-sharded KV
    # ring (parallel.context).  CP shards the sequence, not the weights, so
    # it is mutually exclusive with tensor-MP compute — the model axis hosts
    # the ring and every parameter stays replicated across it.
    context_axis: Optional[str] = None

    @property
    def ep(self) -> bool:
        return self.mesh is not None and self.model_axis is not None


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _attn_init(key, cfg, dtype, cross: bool = False):
    d, hd = cfg.d_model, cfg.head_dim
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    return {
        "wq": L.dense_init(ks[0], d, nh * hd, dtype),
        "wk": L.dense_init(ks[1], d, nkv * hd, dtype),
        "wv": L.dense_init(ks[2], d, nkv * hd, dtype),
        "wo": L.dense_init(ks[3], nh * hd, d, dtype),
    }


def layer_init(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    if cfg.rwkv:
        return rwkv_mod.rwkv_layer_init(key, cfg, dtype)
    p = {"ln1": jnp.ones((d,), jnp.float32), "ln2": jnp.ones((d,), jnp.float32)}
    p["attn"] = _attn_init(ks[0], cfg, dtype)
    if cfg.family == "hybrid":
        p["ssm"] = ssm_mod.ssm_init(ks[1], cfg, dtype)
        p["beta_attn"] = jnp.ones((d,), jnp.float32)
        p["beta_ssm"] = jnp.ones((d,), jnp.float32)
        p["ln_attn_out"] = jnp.ones((d,), jnp.float32)
        p["ln_ssm_out"] = jnp.ones((d,), jnp.float32)
    if cfg.encoder_layers:  # whisper decoder: cross attention
        p["lnx"] = jnp.ones((d,), jnp.float32)
        p["xattn"] = _attn_init(ks[2], cfg, dtype, cross=True)
    if cfg.is_moe:
        p["moe"] = moe_mod.moe_init(ks[3], cfg, dtype)
    else:
        p["mlp"] = L.mlp_init(ks[3], d, cfg.d_ff, cfg.mlp_kind, dtype)
    return p


def _encoder_layer_init(key, cfg, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 2)
    return {
        "ln1": jnp.ones((d,), jnp.float32), "ln2": jnp.ones((d,), jnp.float32),
        "attn": _attn_init(ks[0], cfg, dtype),
        "mlp": L.mlp_init(ks[1], d, cfg.d_ff, "gelu", dtype),
    }


def model_init(key, cfg):
    dtype = jnp.dtype(cfg.param_dtype)
    d, v = cfg.d_model, cfg.vocab_padded
    ks = jax.random.split(key, 8)
    params = {
        "embed": L.embed_init(ks[0], v, d, dtype),
        "final_norm": jnp.ones((d,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(ks[1], d, v, dtype)
    lkeys = jax.random.split(ks[2], cfg.n_layers)
    params["layers"] = jax.vmap(lambda k: layer_init(k, cfg, dtype))(lkeys)
    if cfg.n_prefix_embeds:       # VLM: projector for precomputed patch embeds
        params["prefix_proj"] = L.dense_init(ks[3], d, d, dtype)
    if cfg.encoder_layers:        # whisper: encoder over stub frame embeddings
        ekeys = jax.random.split(ks[4], cfg.encoder_layers)
        params["encoder"] = {
            "layers": jax.vmap(lambda k: _encoder_layer_init(k, cfg, dtype))(ekeys),
            "pos_embed": (jax.random.normal(ks[5], (cfg.encoder_seq, d)) * 0.02
                          ).astype(dtype),
            "final_norm": jnp.ones((d,), jnp.float32),
        }
    return params


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def make_cache(cfg, batch: int, capacity: int, *, window: int = 0,
               dtype=jnp.bfloat16):
    """Decode cache, stacked over layers.  ``window``>0 => ring buffer of that
    size.  RWKV/SSM carry recurrent state instead of KV."""
    Lc = cfg.n_layers
    cache = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.rwkv:
        d, hd = cfg.d_model, cfg.head_dim or 64
        h = d // hd
        cache["wkv_S"] = jnp.zeros((Lc, batch, h, hd, hd), jnp.float32)
        cache["tm_x"] = jnp.zeros((Lc, batch, d), dtype)
        cache["cm_x"] = jnp.zeros((Lc, batch, d), dtype)
        return cache
    length = window if window else capacity
    cache["k"] = jnp.zeros((Lc, batch, length, cfg.n_kv_heads, cfg.head_dim), dtype)
    cache["v"] = jnp.zeros_like(cache["k"])
    if cfg.family == "hybrid":
        di = cfg.ssm_expand * cfg.d_model
        cache["ssm_h"] = jnp.zeros((Lc, batch, di, cfg.ssm_state), jnp.float32)
        cache["ssm_conv"] = jnp.zeros((Lc, batch, cfg.ssm_conv - 1, di), dtype)
    if cfg.encoder_layers:
        cache["xk"] = jnp.zeros((Lc, batch, cfg.encoder_seq, cfg.n_kv_heads,
                                 cfg.head_dim), dtype)
        cache["xv"] = jnp.zeros_like(cache["xk"])
    return cache


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _cache_seq_sharded(cfg, cache_kv, pctx) -> bool:
    """Mirror of the flash-decode engagement condition (§Perf B.2/B.3)."""
    if pctx is None or pctx.mesh is None or pctx.model_axis is None:
        return False
    clen = cache_kv["k"].shape[1]
    return (clen % pctx.mesh.shape[pctx.model_axis] == 0 and clen >= 1024
            and not cfg.attn_logit_softcap)


def _batch_div(b, pctx, baxes) -> bool:
    n = 1
    for a in baxes:
        n *= pctx.mesh.shape[a]
    return n > 1 and b % n == 0


def _attn_batch_respec(pctx, cfg, b: int, t: int = 0):
    """When the head count does not divide the model axis (e.g. smollm's 15
    heads on 16-way MP), attention cannot be head-sharded — instead of
    replicating the quadratic attention work on every model shard, reshard
    around the attention einsums.  Two fallbacks, tried in order:

      1. batch-over-(dp x model): needs B % (dp*mp) == 0 (train_4k);
      2. sequence-over-model on the QUERY dim only (§Perf iteration A):
         q and out shard their time dim on the model axis while K/V stay
         replicated — each shard computes its S/mp query rows against all
         keys, which is exactly 1/mp of the work and is mask-correct for
         causal + sliding-window (masks are elementwise on iota positions).
         Needs T % mp == 0 (prefill_32k and train_4k both qualify).

    Returns (q_spec, kv_spec, out_spec) NamedShardings or (None,)*3.
    """
    if pctx is None or pctx.mesh is None or pctx.model_axis is None or not cfg.n_heads:
        return None, None, None
    msz = pctx.mesh.shape[pctx.model_axis]
    if cfg.n_heads % msz == 0:
        return None, None, None  # head sharding works; GSPMD handles it
    baxes = tuple(a for a in pctx.batch_axes if a)
    dp = 1
    for a in baxes:
        dp *= pctx.mesh.shape[a]
    NS = jax.sharding.NamedSharding
    if b % (dp * msz) == 0:
        inner = NS(pctx.mesh, P(baxes + (pctx.model_axis,), None, None, None))
        outer = NS(pctx.mesh, P(baxes or None, None, None, None))
        return inner, inner, outer
    if t and t % msz == 0 and t > msz:
        q_spec = NS(pctx.mesh, P(baxes or None, pctx.model_axis, None, None))
        outer = NS(pctx.mesh, P(baxes or None, None, None, None))
        # K/V must be pinned REPLICATED on the model axis: otherwise GSPMD
        # propagates q's seq-sharding onto them and lowers the KV-chunk
        # slicing as per-chunk halo collective-permutes (measured: 97
        # permutes/layer, 29 GB/layer wire — §Perf iteration A.2)
        return q_spec, outer, outer
    return None, None, None


def _self_attention(p, x, cfg, *, window: int, pos0, cache_kv=None,
                    cache_len=None, pctx=None):
    """Self-attention over x (+ optional cache for decode).

    Returns (out, (k_roped, v)) — roped keys for cache insertion.
    """
    b, t, d = x.shape
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, t, nh, hd)
    k = (x @ p["wk"].astype(x.dtype)).reshape(b, t, nkv, hd)
    v = (x @ p["wv"].astype(x.dtype)).reshape(b, t, nkv, hd)
    q_spec, kv_spec, out_spec = _attn_batch_respec(pctx, cfg, b, t)
    if q_spec is not None and cache_kv is None:
        q = jax.lax.with_sharding_constraint(q, q_spec)
        if kv_spec is not None:
            k = jax.lax.with_sharding_constraint(k, kv_spec)
            v = jax.lax.with_sharding_constraint(v, kv_spec)
    if jnp.ndim(pos0):
        positions = pos0[:, None] + jnp.arange(t)[None]          # (b, t)
    else:
        positions = jnp.broadcast_to(pos0 + jnp.arange(t), (b, t))
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    if cache_kv is None:
        out = L.attention(q, k, v, causal=True, q_start=0, window=window,
                          softcap=cfg.attn_logit_softcap)
    elif jnp.ndim(cache_len) == 1:
        # slot mode (continuous batching): per-request positions against a
        # LINEAR cache of full capacity — a sliding window is enforced by
        # mask, not by ring storage, so mid-flight requests at different
        # positions coexist in one batch.  Valid keys for query i of row r
        # (absolute position pos_r + i): filled cache slots s < pos_r, plus
        # appended chunk tokens j <= i (causal within the chunk — this is
        # what makes multi-token chunked prefill against a cache correct).
        k_all = jnp.concatenate([cache_kv["k"], k], axis=1)
        v_all = jnp.concatenate([cache_kv["v"], v], axis=1)
        clen = cache_kv["k"].shape[1]
        slot = jnp.arange(clen + t)
        in_cache = slot < clen                                   # (clen+t,)
        qpos = positions                                         # (b, t)
        kpos = jnp.where(in_cache[None], slot[None],
                         pos0[:, None] + (slot[None] - clen))    # (b, clen+t)
        valid = jnp.where(in_cache[None, None],
                          slot[None, None, :] < pos0[:, None, None],
                          kpos[:, None, :] <= qpos[:, :, None])
        if window:
            valid &= kpos[:, None, :] > qpos[:, :, None] - window
        out = L.attention(q, k_all, v_all, mask=valid,
                          softcap=cfg.attn_logit_softcap)
    elif (pctx is not None and pctx.mesh is not None
          and pctx.model_axis is not None and t == 1
          and cache_kv["k"].shape[1] % pctx.mesh.shape[pctx.model_axis] == 0
          and cache_kv["k"].shape[1] >= 1024
          and not cfg.attn_logit_softcap):
        # flash-decode: KV cache sequence-sharded over the model axis
        # (§Perf iteration B.2) — partial softmax per shard, pmax/psum merge
        clen = cache_kv["k"].shape[1]
        slot = jnp.arange(clen)
        if window:
            # seq-sharded ring writes at pos % clen (see the insert below):
            # every written slot except the one about to be overwritten
            # (holding absolute position pos - clen, outside the window)
            cvalid = (slot < cache_len) & (slot != cache_len % clen)
        else:
            cvalid = slot < cache_len
        cvalid = jnp.broadcast_to(cvalid, (b, clen))
        baxes = tuple(a for a in pctx.batch_axes if a)
        out = L.seq_sharded_decode_attention(
            q, cache_kv["k"], cache_kv["v"], cvalid, k, v,
            mesh=pctx.mesh, seq_axis=pctx.model_axis,
            batch_axes=baxes if _batch_div(b, pctx, baxes) else ())
        out = out.reshape(b, t, nh * hd)
        return out @ p["wo"].astype(x.dtype), (k, v)
    else:
        k_all = jnp.concatenate([cache_kv["k"], k], axis=1)
        v_all = jnp.concatenate([cache_kv["v"], v], axis=1)
        clen = cache_kv["k"].shape[1]
        slot = jnp.arange(clen + t)
        if window:
            # shift-left ring: the newest slots hold the most recent tokens;
            # the query (at absolute pos cache_len) sees positions in
            # (pos - window, pos], i.e. at most window-1 cache entries plus
            # itself — the oldest ring slot is always masked
            n_valid = jnp.minimum(cache_len, window - 1)
            valid = (slot >= clen - n_valid)
        else:
            # linear buffer: first cache_len slots valid + appended tokens
            valid = (slot < cache_len) | (slot >= clen)
        kv_mask = jnp.broadcast_to(valid, (b, clen + t))
        out = L.attention(q, k_all, v_all, causal=False, kv_mask=kv_mask,
                          softcap=cfg.attn_logit_softcap,
                          dense_threshold=max(8192, clen + t + 1))
    if q_spec is not None and cache_kv is None:
        out = jax.lax.with_sharding_constraint(out, out_spec)
    out = out.reshape(b, t, nh * hd)
    return out @ p["wo"].astype(x.dtype), (k, v)


def _cross_attention(p, x, enc_kv, cfg):
    b, t, d = x.shape
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, t, nh, hd)
    out = L.attention(q, enc_kv[0], enc_kv[1], causal=False,
                      dense_threshold=max(8192, enc_kv[0].shape[1] + 1))
    return out.reshape(b, t, nh * hd) @ p["wo"].astype(x.dtype)


def _enc_kv(p, enc_out, cfg):
    b, f, d = enc_out.shape
    nkv, hd = cfg.n_kv_heads, cfg.head_dim
    k = (enc_out @ p["wk"].astype(enc_out.dtype)).reshape(b, f, nkv, hd)
    v = (enc_out @ p["wv"].astype(enc_out.dtype)).reshape(b, f, nkv, hd)
    return k, v


def block_apply(cfg, p, x, *, mode: str, window: int, pos0, cache=None,
                enc_out=None, pctx: Optional[ParallelCtx] = None,
                rwkv_chunked: bool = False, capacity_factor=1.25):
    """One decoder block.  Returns (x, new_cache (or None), aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache = {}
    if cfg.rwkv:
        if mode == "decode":
            tm_out, tm_x, S = rwkv_mod.rwkv_time_mix(
                p["tm"], L.rms_norm(x, p["ln1"], cfg.norm_eps),
                cache["tm_x"], cache["wkv_S"], cfg)
            x = x + tm_out
            cm_out, cm_x = rwkv_mod.rwkv_channel_mix(
                p["cm"], L.rms_norm(x, p["ln2"], cfg.norm_eps), cache["cm_x"])
            x = x + cm_out
            new_cache = {"wkv_S": S, "tm_x": tm_x, "cm_x": cm_x}
        else:
            b, d = x.shape[0], x.shape[-1]
            zero = jnp.zeros((b, d), x.dtype)
            tm_out, tm_x, S = rwkv_mod.rwkv_time_mix(
                p["tm"], L.rms_norm(x, p["ln1"], cfg.norm_eps), zero, None, cfg,
                chunked=rwkv_chunked)
            x = x + tm_out
            cm_out, cm_x = rwkv_mod.rwkv_channel_mix(
                p["cm"], L.rms_norm(x, p["ln2"], cfg.norm_eps), zero)
            x = x + cm_out
            if mode == "prefill":
                new_cache = {"wkv_S": S, "tm_x": tm_x, "cm_x": cm_x}
        return x, new_cache, aux

    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    if mode == "decode":
        cache_kv = {"k": cache["k"], "v": cache["v"]}
        attn_out, (k_new, v_new) = _self_attention(
            p["attn"], h, cfg, window=window, pos0=pos0, cache_kv=cache_kv,
            cache_len=pos0, pctx=pctx)
        seq_sharded = (_cache_seq_sharded(cfg, cache_kv, pctx)
                       and jnp.ndim(pos0) == 0)
        if jnp.ndim(pos0):
            # slot mode: always the per-row positional insert — the sliding
            # window (if any) was already applied as a mask above
            kv = L.cache_insert_at(cache_kv, k_new, v_new, pos0)
        elif window and not seq_sharded:
            kv = L.cache_insert_window(cache_kv, k_new, v_new)
        elif seq_sharded:
            # windowed ring caches also take the positional-insert path when
            # seq-sharded: write at pos % window (ring without the shift)
            clen = cache_kv["k"].shape[1]
            wpos = pos0 % clen if window else pos0
            baxes = tuple(a for a in pctx.batch_axes if a)
            ck, cv = L.seq_sharded_cache_insert(
                cache_kv["k"], cache_kv["v"], k_new, v_new, wpos,
                mesh=pctx.mesh, seq_axis=pctx.model_axis,
                batch_axes=baxes if _batch_div(x.shape[0], pctx, baxes) else ())
            kv = {"k": ck, "v": cv}
        else:
            kv = L.cache_insert_full(cache_kv, k_new, v_new, pos0)
        new_cache.update(kv)
    else:
        attn_out, (k_new, v_new) = _self_attention(
            p["attn"], h, cfg, window=window, pos0=pos0, pctx=pctx)
        if mode == "prefill":
            if window:
                w = window
                s_len = k_new.shape[1]
                n = min(s_len, w)
                if _cache_seq_sharded(cfg, {"k": jnp.zeros(
                        (1, w, 1, 1))}, pctx):
                    # positional ring layout (slot = pos % w) — matches the
                    # seq-sharded decode insert (§Perf B.3)
                    idx = jnp.arange(s_len - n, s_len) % w
                    ks = jnp.zeros((k_new.shape[0], w) + k_new.shape[2:],
                                   k_new.dtype).at[:, idx].set(k_new[:, -n:])
                    vs = jnp.zeros_like(ks).at[:, idx].set(v_new[:, -n:])
                else:
                    # shift-left layout (single-device serving engine)
                    pad = w - n
                    ks = jnp.pad(k_new[:, -w:],
                                 ((0, 0), (pad, 0), (0, 0), (0, 0)))
                    vs = jnp.pad(v_new[:, -w:],
                                 ((0, 0), (pad, 0), (0, 0), (0, 0)))
                new_cache.update({"k": ks, "v": vs})
            else:
                # per-layer cache slice: (B, capacity, KV, hd)
                cap = cache["k"].shape[1] if isinstance(cache, dict) else k_new.shape[1]
                ks = jnp.pad(k_new, ((0, 0), (0, cap - k_new.shape[1]), (0, 0), (0, 0)))
                vs = jnp.pad(v_new, ((0, 0), (0, cap - v_new.shape[1]), (0, 0), (0, 0)))
                new_cache.update({"k": ks, "v": vs})

    if cfg.family == "hybrid":
        ssm_state = None
        if mode == "decode":
            ssm_state = {"h": cache["ssm_h"], "conv": cache["ssm_conv"]}
        ssm_out, ssm_state_new = ssm_mod.ssm_apply(p["ssm"], h, cfg, ssm_state)
        attn_out = 0.5 * (
            L.rms_norm(attn_out, p["ln_attn_out"], cfg.norm_eps)
            * p["beta_attn"].astype(x.dtype)
            + L.rms_norm(ssm_out, p["ln_ssm_out"], cfg.norm_eps)
            * p["beta_ssm"].astype(x.dtype))
        if mode in ("decode", "prefill"):
            new_cache.update({"ssm_h": ssm_state_new["h"],
                              "ssm_conv": ssm_state_new["conv"]})
    x = x + attn_out

    if cfg.encoder_layers:
        hx = L.rms_norm(x, p["lnx"], cfg.norm_eps)
        if mode == "decode":
            enc_kv = (cache["xk"], cache["xv"])
            new_cache.update({"xk": cache["xk"], "xv": cache["xv"]})
        else:
            enc_kv = _enc_kv(p["xattn"], enc_out, cfg)
            if mode == "prefill":
                new_cache.update({"xk": enc_kv[0], "xv": enc_kv[1]})
        x = x + _cross_attention(p["xattn"], hx, enc_kv, cfg)

    h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.is_moe:
        # decode batches are tiny: use the no-drop capacity so cached decoding
        # is numerically identical to teacher-forced forward
        cf = None if mode == "decode" else capacity_factor
        if pctx is not None and pctx.ep:
            d, e = cfg.d_model, cfg.n_experts
            ma = pctx.model_axis
            fa = tuple(pctx.moe_ff_axes)
            fspec = fa if fa else None
            # 2D EP replicates the (tiny) decode activations across the ff
            # axes; otherwise tokens stay batch-sharded over the DP axes
            bspec = P(None, None, None) if fa else P(pctx.batch_axes, None, None)
            in_specs = (
                {"router": P(),
                 "wi": P(ma, None, fspec), "wg": P(ma, None, fspec),
                 "wo": P(ma, fspec, None),
                 **({"shared": {"wi": P(None, ma), "wg": P(None, ma),
                                "wo": P(ma, None)}} if "shared" in p["moe"] else {})},
                bspec)
            fn = functools.partial(moe_mod.moe_ffn, cfg=cfg, model_axis=ma,
                                   ff_axes=fa, capacity_factor=cf)
            mlp_out, moe_aux = shard_map(
                fn, mesh=pctx.mesh, in_specs=in_specs,
                out_specs=(bspec, P()))(p["moe"], h2)
        else:
            mlp_out, moe_aux = moe_mod.moe_ffn(p["moe"], h2, cfg,
                                               capacity_factor=cf)
        aux = aux + cfg.router_aux_loss * moe_aux
    else:
        mlp_out = L.mlp_apply(p["mlp"], h2, cfg.mlp_kind)
    x = x + mlp_out
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# overlapped tensor-MP block (comm_runtime="overlapped")
# ---------------------------------------------------------------------------

def overlapped_arch_supported(cfg) -> bool:
    """Arch classes whose decoder block the overlap-scheduled collective
    matmuls can execute: homogeneous dense blocks only (no MoE / SSM / RWKV
    / enc-dec / VLM prefix / CNN / RNN).  ONE predicate shared by the
    runtime gate below and the planner's credit gate
    (``core.planner.comm_runtime_supported``) so the two can never drift —
    the planner must not credit an overlap the runtime will not execute."""
    return not (cfg.is_moe or cfg.rwkv
                or cfg.family in ("hybrid", "ssm", "cnn", "rnn")
                or cfg.encoder_layers or cfg.n_prefix_embeds)


def overlapped_supported(cfg, pctx: Optional[ParallelCtx],
                         t: int) -> bool:
    """Can this (arch, mesh, shape) run the overlap-scheduled collective
    matmuls?  Requires ``overlapped_arch_supported``, q heads and FFN hidden
    divisible by the model axis, and the sequence divisible so the residual
    stream can stay sequence-sharded between blocks.  Anything else falls
    back to GSPMD — the ShardingRules fallback warning makes the perf cliff
    visible."""
    if (pctx is None or pctx.comm_runtime != "overlapped"
            or pctx.mesh is None or pctx.model_axis is None):
        return False
    msz = pctx.mesh.shape[pctx.model_axis]
    if msz <= 1:
        return False
    if not overlapped_arch_supported(cfg):
        return False
    return (cfg.n_heads > 0 and cfg.n_heads % msz == 0
            and cfg.d_ff % msz == 0 and t % msz == 0
            and t // msz % max(pctx.comm_chunks, 1) == 0)


def _self_attention_overlapped(p, x, cfg, *, window: int, axis: str, msz: int,
                               chunks: int):
    """Self-attention with q/k/v/o on the collective-matmul rings, for use
    inside the block shard_map.  ``x``: (B, T/m, d) sequence-sharded.  Query
    heads shard over ``axis``; KV heads shard too when divisible, otherwise
    every shard computes the full (small, GQA) KV from the gathered x —
    both cases ride the single qkv gather ring.  Output returns through a
    ``matmul_reduce_scatter`` (row-parallel wo)."""
    from repro.parallel.collectives import (all_gather_matmul,
                                            matmul_reduce_scatter)
    b, t_loc, d = x.shape
    t = t_loc * msz
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    hpm = nh // msz
    kv_sharded = nkv % msz == 0
    kvpm = nkv // msz if kv_sharded else nkv
    kw = dict(axis=axis, axis_size=msz, chunks=chunks)
    # one gather ring computes q (sharded) and k/v (sharded or replicated)
    w_qkv = jnp.concatenate(
        [p["wq"].astype(x.dtype), p["wk"].astype(x.dtype),
         p["wv"].astype(x.dtype)], axis=1)
    qkv = all_gather_matmul(x, w_qkv, **kw)              # (b, t, ...)
    q = qkv[..., :hpm * hd].reshape(b, t, hpm, hd)
    k = qkv[..., hpm * hd:(hpm + kvpm) * hd].reshape(b, t, kvpm, hd)
    v = qkv[..., (hpm + kvpm) * hd:].reshape(b, t, kvpm, hd)
    positions = jnp.arange(t)
    q = L.apply_rope(q, jnp.broadcast_to(positions, (b, t)), cfg.rope_theta)
    k = L.apply_rope(k, jnp.broadcast_to(positions, (b, t)), cfg.rope_theta)
    if not kv_sharded:
        # replicated KV: take the q-head-aligned slice of the repeated heads
        j = jax.lax.axis_index(axis)
        k = jax.lax.dynamic_slice_in_dim(L.repeat_kv(k, nh // nkv),
                                         j * hpm, hpm, axis=2)
        v = jax.lax.dynamic_slice_in_dim(L.repeat_kv(v, nh // nkv),
                                         j * hpm, hpm, axis=2)
    out = L.attention(q, k, v, causal=True, q_start=0, window=window,
                      softcap=cfg.attn_logit_softcap)
    out = out.reshape(b, t, hpm * hd)
    return matmul_reduce_scatter(out, p["wo"].astype(x.dtype), **kw)


def overlapped_block_apply(cfg, p, x, *, window: int,
                           pctx: ParallelCtx):
    """One dense decoder block with every Megatron matmul on the chunked
    collective rings, the residual stream sequence-sharded over the model
    axis end to end (train mode): ln1 -> qkv gather ring -> attention (full
    sequence per head shard) -> wo reduce ring -> residual -> ln2 -> MLP
    gather/reduce rings -> residual.  ``x`` enters and leaves (B, T, d)
    GSPMD-global, sharded P(batch, model, None) — stacking these blocks in
    the layer scan keeps the hot path free of monolithic collectives."""
    mesh, axis = pctx.mesh, pctx.model_axis
    msz = mesh.shape[axis]
    chunks = max(pctx.comm_chunks, 1)
    baxes = tuple(a for a in pctx.batch_axes if a)
    bspec = baxes if (baxes and _batch_div(x.shape[0], pctx, baxes)) else None
    kv_sharded = cfg.n_kv_heads % msz == 0

    def local(lp, xl):
        h = L.rms_norm(xl, lp["ln1"], cfg.norm_eps)
        xl = xl + _self_attention_overlapped(lp["attn"], h, cfg,
                                             window=window, axis=axis,
                                             msz=msz, chunks=chunks)
        h2 = L.rms_norm(xl, lp["ln2"], cfg.norm_eps)
        return xl + L.mlp_apply_overlapped(lp["mlp"], h2, cfg.mlp_kind,
                                           axis=axis, axis_size=msz,
                                           chunks=chunks)

    col, row = P(None, axis), P(axis, None)
    kv = col if kv_sharded else P(None, None)
    p_specs = {"ln1": P(None), "ln2": P(None),
               "attn": {"wq": col, "wk": kv, "wv": kv, "wo": row},
               "mlp": {k: (row if k == "wo" else col) for k in p["mlp"]}}
    xspec = P(bspec, axis, None)
    return shard_map(local, mesh=mesh, in_specs=(p_specs, xspec),
                     out_specs=xspec)(p, x)


# ---------------------------------------------------------------------------
# context-parallel block (sequence-sharded ring attention)
# ---------------------------------------------------------------------------

def cp_supported(cfg, pctx: Optional[ParallelCtx], t: int) -> bool:
    """Can this (arch, mesh, shape) run context-parallel ring attention?
    Requires a homogeneous dense decoder (same predicate as the overlapped
    runtime — ``overlapped_arch_supported``), no logit softcap (the ring's
    online-softmax fold has no capped variant), and the sequence divisible
    by the ring size so the residual stream stays sequence-sharded between
    blocks.  Anything else falls back to GSPMD."""
    if pctx is None or pctx.context_axis is None or pctx.mesh is None:
        return False
    csz = pctx.mesh.shape[pctx.context_axis]
    if csz <= 1:
        return False
    if not overlapped_arch_supported(cfg) or cfg.attn_logit_softcap:
        return False
    return cfg.n_heads > 0 and t % csz == 0


def cp_block_apply(cfg, p, x, *, window: int, pctx: ParallelCtx):
    """One dense decoder block with the residual stream SEQUENCE-sharded
    over the context axis and attention on the KV ppermute ring
    (``parallel.context.ring_attention``).  Unlike the tensor-MP overlapped
    block, every weight stays fully replicated across the ring — CP shards
    the sequence, not the parameters — so qkv/wo/MLP are plain local
    matmuls over this device's T/m rows and the ONLY communication in the
    compiled block is the ring's collective-permutes (fwd and bwd; HLO
    asserted in tests).  ``x`` enters and leaves (B, T, d) GSPMD-global,
    sharded P(batch, context, None)."""
    from repro.parallel.context import ring_attention
    mesh, axis = pctx.mesh, pctx.context_axis
    csz = mesh.shape[axis]
    baxes = tuple(a for a in pctx.batch_axes if a)
    bspec = baxes if (baxes and _batch_div(x.shape[0], pctx, baxes)) else None
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    t_loc = x.shape[1] // csz

    def local(lp, xl):
        b = xl.shape[0]
        h = L.rms_norm(xl, lp["ln1"], cfg.norm_eps)
        q = (h @ lp["attn"]["wq"].astype(h.dtype)).reshape(b, t_loc, nh, hd)
        k = (h @ lp["attn"]["wk"].astype(h.dtype)).reshape(b, t_loc, nkv, hd)
        v = (h @ lp["attn"]["wv"].astype(h.dtype)).reshape(b, t_loc, nkv, hd)
        j = jax.lax.axis_index(axis)
        positions = jnp.broadcast_to(j * t_loc + jnp.arange(t_loc),
                                     (b, t_loc))
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        out = ring_attention(q, k, v, axis=axis, axis_size=csz,
                             causal=True, window=window)
        xl = xl + (out.reshape(b, t_loc, nh * hd)
                   @ lp["attn"]["wo"].astype(xl.dtype))
        h2 = L.rms_norm(xl, lp["ln2"], cfg.norm_eps)
        return xl + L.mlp_apply(lp["mlp"], h2, cfg.mlp_kind)

    rp, rw = P(None), P(None, None)
    p_specs = {"ln1": rp, "ln2": rp,
               "attn": {k: rw for k in p["attn"]},
               "mlp": {k: rw for k in p["mlp"]}}
    sub = {k: p[k] for k in ("ln1", "ln2", "attn", "mlp")}
    xspec = P(bspec, axis, None)
    return shard_map(local, mesh=mesh, in_specs=(p_specs, xspec),
                     out_specs=xspec)(sub, x)


# ---------------------------------------------------------------------------
# encoder (whisper)
# ---------------------------------------------------------------------------

def encode(cfg, params, frames):
    """frames: (B, F, d) stub frontend embeddings -> (B, F, d)."""
    enc = params["encoder"]
    x = frames + enc["pos_embed"][None].astype(frames.dtype)

    def body(x, lp):
        h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        b, f, d = h.shape
        nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        q = (h @ lp["attn"]["wq"].astype(h.dtype)).reshape(b, f, nh, hd)
        k = (h @ lp["attn"]["wk"].astype(h.dtype)).reshape(b, f, nkv, hd)
        v = (h @ lp["attn"]["wv"].astype(h.dtype)).reshape(b, f, nkv, hd)
        o = L.attention(q, k, v, causal=False, dense_threshold=max(8192, f + 1))
        x = x + o.reshape(b, f, nh * hd) @ lp["attn"]["wo"].astype(h.dtype)
        h2 = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + L.mlp_apply(lp["mlp"], h2, "gelu")
        return x, None

    x, _ = jax.lax.scan(body, x, enc["layers"],
                        unroll=cfg.encoder_layers if L.analysis_unroll() else 1)
    return L.rms_norm(x, enc["final_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# top-level entry points
# ---------------------------------------------------------------------------

def _embed(cfg, params, tokens):
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    return x * (cfg.d_model ** 0.5 if cfg.tie_embeddings else 1.0)


def _head(cfg, params, x):
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ w.astype(x.dtype)
    if cfg.vocab_padded != cfg.vocab_size:
        neg = jnp.full((cfg.vocab_padded - cfg.vocab_size,), L.NEG_INF, logits.dtype)
        bias = jnp.concatenate([jnp.zeros((cfg.vocab_size,), logits.dtype), neg])
        logits = logits + bias
    return logits


def forward(cfg, params, batch, *, mode: str = "train", window_override=None,
            pctx: Optional[ParallelCtx] = None, remat: bool = True,
            rwkv_chunked: bool = False, cache_capacity: int = 0,
            capacity_factor=1.25):
    """Main entry.  batch: dict(tokens (B,S) [, prefix (B,P,d), frames (B,F,d)]).

    mode "train": returns (logits, aux).  mode "prefill": returns
    (logits, cache, aux) with a cache of ``cache_capacity``.
    """
    window = cfg.sliding_window if window_override is None else window_override
    tokens = batch["tokens"]
    x = _embed(cfg, params, tokens)
    n_prefix = 0
    if cfg.n_prefix_embeds:
        pre = batch["prefix"].astype(x.dtype) @ params["prefix_proj"].astype(x.dtype)
        x = jnp.concatenate([pre, x], axis=1)
        n_prefix = pre.shape[1]
    enc_out = None
    if cfg.encoder_layers:
        enc_out = encode(cfg, params, batch["frames"].astype(x.dtype))

    prefill = mode == "prefill"
    cache_tmpl = None
    if prefill:
        cache_tmpl = make_cache(cfg, tokens.shape[0], cache_capacity or x.shape[1],
                                window=window, dtype=jnp.dtype(cfg.dtype))

    overlapped = (not prefill
                  and overlapped_supported(cfg, pctx, x.shape[1]))
    cp = (not prefill and not overlapped
          and cp_supported(cfg, pctx, x.shape[1]))
    if (not cp and not prefill and pctx is not None
            and pctx.context_axis is not None and pctx.mesh is not None
            and pctx.mesh.shape[pctx.context_axis] > 1):
        # same perf-cliff visibility rule as the overlapped fallback below
        cpn = pctx.mesh.shape[pctx.context_axis]
        warnings.warn(
            f"[context] {cfg.name}: context parallelism requested but the "
            f"KV ring cannot engage (needs a homogeneous dense decoder "
            f"without logit softcap and seq ({x.shape[1]}) % {cpn} == 0); "
            f"falling back to GSPMD's gathered attention", stacklevel=2)
    if (not overlapped and not prefill and pctx is not None
            and pctx.comm_runtime == "overlapped"
            and pctx.mesh is not None and pctx.model_axis is not None
            and pctx.mesh.shape[pctx.model_axis] > 1):
        # an explicitly requested runtime silently running something else is
        # the same perf cliff the ShardingRules fallback warning exposes
        mp = pctx.mesh.shape[pctx.model_axis]
        warnings.warn(
            f"[collectives] {cfg.name}: comm_runtime='overlapped' requested "
            f"but the overlapped block cannot engage (needs a homogeneous "
            f"dense decoder with n_heads ({cfg.n_heads}) and d_ff "
            f"({cfg.d_ff}) divisible by the {mp}-way model axis, seq "
            f"({x.shape[1]}) % {mp} == 0 and (seq/mp) % comm_chunks "
            f"({pctx.comm_chunks}) == 0); falling back to GSPMD's "
            f"monolithic collectives", stacklevel=2)

    def body(carry, lp_and_cache):
        x, aux = carry
        if prefill:
            lp, csl = lp_and_cache
        else:
            lp, csl = lp_and_cache, None
        if overlapped:
            x = overlapped_block_apply(cfg, lp, x, window=window, pctx=pctx)
            return (x, aux), 0
        if cp:
            x = cp_block_apply(cfg, lp, x, window=window, pctx=pctx)
            return (x, aux), 0
        x, c_new, a = block_apply(cfg, lp, x, mode="prefill" if prefill else "train",
                                  window=window, pos0=0, cache=csl,
                                  enc_out=enc_out, pctx=pctx,
                                  rwkv_chunked=rwkv_chunked,
                                  capacity_factor=capacity_factor)
        return (x, aux + a), (c_new if prefill else 0)

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    if prefill:
        xs = (params["layers"], {k: v for k, v in cache_tmpl.items() if k != "pos"})
    else:
        xs = params["layers"]
    (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs,
                                    unroll=cfg.n_layers if L.analysis_unroll() else 1)
    if n_prefix:
        x = x[:, n_prefix:]
    logits = _head(cfg, params, x)
    if prefill:
        caches["pos"] = jnp.asarray(tokens.shape[1] + n_prefix, jnp.int32)
        return logits, caches, aux
    return logits, aux


def pipeline_stage_fn(cfg, *, remat: bool = True, rwkv_chunked: bool = False,
                      window_override=None):
    """One pipeline chunk of the decoder stack as a pure shape-preserving
    ``(chunk_params, x) -> y`` callable — the unit both pipeline runtimes
    place per ``WorkUnit`` and the hand-scheduled runtime ``jax.vjp``'s."""
    window = cfg.sliding_window if window_override is None else window_override

    def stage_fn(sp, x):
        def body(x, lp):
            y, _, _ = block_apply(cfg, lp, x, mode="train", window=window,
                                  pos0=0, rwkv_chunked=rwkv_chunked)
            return y, None

        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = jax.lax.scan(body, x, sp)
        return x

    return stage_fn


def forward_pipeline(cfg, params, batch, *, mesh, axis: str, n_micro: int,
                     remat: bool = True, rwkv_chunked: bool = False,
                     window_override=None, schedule: str = "gpipe",
                     virtual_stages: int = 1, batch_axes=()):
    """Train-mode forward with the decoder stack partitioned into pipeline
    stages over mesh ``axis`` (``parallel.pipeline``), ``n_micro``
    micro-batches in flight under the requested ``schedule``; ``batch_axes``
    shards each micro-batch over the DP mesh axes.  Supported for
    homogeneous decoder-only stacks (no encoder, no prefix embeds, no MoE
    aux loss); embed and head stay replicated on every stage.  Returns
    logits only."""
    from repro.parallel.pipeline import pipeline_apply, stack_to_stages

    x = _embed(cfg, params, batch["tokens"])
    n_stages = mesh.shape[axis]
    stages = stack_to_stages(params["layers"], n_stages, virtual_stages)
    stage_fn = pipeline_stage_fn(cfg, remat=remat, rwkv_chunked=rwkv_chunked,
                                 window_override=window_override)
    x = pipeline_apply(mesh, axis, stage_fn, stages, x, n_micro=n_micro,
                       schedule=schedule, virtual_stages=virtual_stages,
                       batch_axes=batch_axes)
    return _head(cfg, params, x)


def decode_step(cfg, params, cache, batch, *, window_override=None,
                pctx: Optional[ParallelCtx] = None):
    """Decode against the cache.  batch: dict(tokens (B,t)).  Returns
    (logits (B,t,V), new_cache).

    ``cache["pos"]`` scalar: the classic static-batch one-token step (t=1).
    ``cache["pos"]`` (B,): slot mode — per-request positions in a linear
    capacity cache (continuous batching), where t >= 1 also serves as the
    chunked-prefill "extend" step (causal within the appended chunk)."""
    window = cfg.sliding_window if window_override is None else window_override
    x = _embed(cfg, params, batch["tokens"])
    pos = cache["pos"]

    def body(x, lp_cache):
        lp, csl = lp_cache
        x, c_new, _ = block_apply(cfg, lp, x, mode="decode", window=window,
                                  pos0=pos, cache=csl, pctx=pctx)
        return x, c_new

    layer_caches = {k: v for k, v in cache.items() if k != "pos"}
    x, new_caches = jax.lax.scan(body, x, (params["layers"], layer_caches),
                                 unroll=cfg.n_layers if L.analysis_unroll() else 1)
    logits = _head(cfg, params, x)
    new_caches["pos"] = pos + batch["tokens"].shape[1]
    return logits, new_caches


# ---------------------------------------------------------------------------
# tensor-MP slot decode (continuous-batching serve engine)
# ---------------------------------------------------------------------------

def decode_slots_tp_supported(cfg, mesh, model_axis, batch_axes,
                              n_slots: int, chunks: int = 1) -> bool:
    """Can the slot-ring decode step execute on this (arch, mesh, slots)?
    Mirrors ``overlapped_supported`` with the SLOT dim in the role the
    sequence dim plays in training: n_slots must divide over dp x mp x
    chunks so the residual stream can stay slot-sharded between blocks."""
    if mesh is None or model_axis is None:
        return False
    msz = mesh.shape[model_axis]
    if msz <= 1 or not overlapped_arch_supported(cfg):
        return False
    dp = 1
    for a in (batch_axes or ()):
        if a:
            dp *= mesh.shape[a]
    return (cfg.n_heads > 0 and cfg.n_heads % msz == 0
            and cfg.d_ff % msz == 0 and n_slots % (dp * msz) == 0
            and (n_slots // (dp * msz)) % max(chunks, 1) == 0)


def decode_slots_tp(cfg, params, cache, batch, *, mesh, model_axis: str,
                    batch_axes=(), comm_chunks: int = 1,
                    window_override=None):
    """One continuous-batching decode tick under a dp x tp mesh, the whole
    layer stack inside ONE shard_map with every Megatron matmul on the
    chunked collective-matmul rings (``parallel.collectives``).

    Decode has one token per request, so the training trick of sharding the
    sequence dim does not apply — instead the SLOT/batch dim is the ring row
    dim: the residual stream stays slot-sharded (B/(dp*mp), d) between
    blocks, ``all_gather_matmul`` reassembles all slots for each shard's
    head slice of qkv, attention runs per-slot against the (KV-head-sharded
    when divisible, else replicated) cache, ``matmul_reduce_scatter``
    returns the slot shard through the row-parallel wo, and the MLP rides
    the same rings.  One ``ring_all_gather`` before the (replicated) head is
    the only full reassembly — no monolithic all-gather/all-reduce appears
    in the compiled per-layer decode HLO.

    batch: dict(tokens (B, 1)); cache: slot cache with per-request
    ``pos`` (B,).  Returns (logits (B,1,V), new_cache)."""
    from repro.parallel.collectives import (all_gather_matmul,
                                            matmul_reduce_scatter,
                                            ring_all_gather)
    window = cfg.sliding_window if window_override is None else window_override
    tokens = batch["tokens"]
    pos = cache["pos"]
    msz = mesh.shape[model_axis]
    baxes = tuple(a for a in (batch_axes or ())
                  if a and mesh.shape.get(a, 1) > 1)
    bspec = baxes if baxes else None
    chunks = max(comm_chunks, 1)
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    hpm = nh // msz
    kv_sharded = nkv % msz == 0
    kvpm = nkv // msz if kv_sharded else nkv
    kw = dict(axis=model_axis, axis_size=msz, chunks=chunks)

    def local(p, layer_caches, tok, ps):
        # tok: (B_loc, 1) and ps: (B_loc,) per data shard, replicated over
        # the model axis; the model shard takes its slot rows of the residual
        b_loc = tok.shape[0]
        rows = b_loc // msz
        x = _embed(cfg, p, tok)[:, 0]                     # (B_loc, d)
        j = jax.lax.axis_index(model_axis)
        xl = jax.lax.dynamic_slice_in_dim(x, j * rows, rows, axis=0)
        clen = layer_caches["k"].shape[2]
        slot = jnp.arange(clen + 1)
        valid = jnp.where(slot[None] < clen, slot[None] < ps[:, None], True)
        if window:
            kpos = jnp.where(slot[None] < clen, slot[None], ps[:, None])
            valid &= kpos > ps[:, None] - window

        def body(xl, lp_cache):
            lp, csl = lp_cache
            h = L.rms_norm(xl, lp["ln1"], cfg.norm_eps)
            w_qkv = jnp.concatenate(
                [lp["attn"]["wq"], lp["attn"]["wk"], lp["attn"]["wv"]],
                axis=1).astype(xl.dtype)
            qkv = all_gather_matmul(h, w_qkv, **kw)       # (B_loc, ...)
            q = qkv[:, :hpm * hd].reshape(b_loc, 1, hpm, hd)
            k = qkv[:, hpm * hd:(hpm + kvpm) * hd].reshape(b_loc, 1, kvpm, hd)
            v = qkv[:, (hpm + kvpm) * hd:].reshape(b_loc, 1, kvpm, hd)
            q = L.apply_rope(q, ps[:, None], cfg.rope_theta)
            k = L.apply_rope(k, ps[:, None], cfg.rope_theta)
            k_all = jnp.concatenate([csl["k"], k], axis=1)
            v_all = jnp.concatenate([csl["v"], v], axis=1)
            if kv_sharded:
                k_att, v_att = k_all, v_all
            else:
                # replicated KV: q-head-aligned slice of the repeated heads
                k_att = jax.lax.dynamic_slice_in_dim(
                    L.repeat_kv(k_all, nh // nkv), j * hpm, hpm, axis=2)
                v_att = jax.lax.dynamic_slice_in_dim(
                    L.repeat_kv(v_all, nh // nkv), j * hpm, hpm, axis=2)
            out = L.attention(q, k_att, v_att, mask=valid[:, None, :],
                              softcap=cfg.attn_logit_softcap)
            xl = xl + matmul_reduce_scatter(
                out.reshape(b_loc, hpm * hd),
                lp["attn"]["wo"].astype(xl.dtype), **kw)
            h2 = L.rms_norm(xl, lp["ln2"], cfg.norm_eps)
            xl = xl + L.mlp_apply_overlapped(lp["mlp"], h2, cfg.mlp_kind,
                                             axis=model_axis, axis_size=msz,
                                             chunks=chunks)
            kv = L.cache_insert_at({"k": csl["k"], "v": csl["v"]}, k, v, ps)
            return xl, kv

        xl, new_caches = jax.lax.scan(
            body, xl, (p["layers"], layer_caches),
            unroll=cfg.n_layers if L.analysis_unroll() else 1)
        x_full = ring_all_gather(xl, **kw)                # (B_loc, d)
        logits = _head(cfg, p, x_full[:, None])
        return logits, new_caches

    col, row = P(None, None, model_axis), P(None, model_axis, None)
    kvw = col if kv_sharded else P(None, None, None)
    p_specs = {"embed": P(None, None), "final_norm": P(None),
               "layers": {"ln1": P(None, None), "ln2": P(None, None),
                          "attn": {"wq": col, "wk": kvw, "wv": kvw,
                                   "wo": row},
                          "mlp": {k: (row if k == "wo" else col)
                                  for k in params["layers"]["mlp"]}}}
    if "lm_head" in params:
        p_specs["lm_head"] = P(None, None)
    kvm = model_axis if kv_sharded else None
    c_spec = P(None, bspec, None, kvm, None)
    layer_caches = {"k": cache["k"], "v": cache["v"]}
    logits, new_caches = shard_map(
        local, mesh=mesh,
        in_specs=(p_specs, {"k": c_spec, "v": c_spec},
                  P(bspec, None), P(bspec)),
        out_specs=(P(bspec, None, None), {"k": c_spec, "v": c_spec}))(
            params, layer_caches, tokens, pos)
    new_caches["pos"] = pos + 1
    return logits, new_caches


# ---------------------------------------------------------------------------
# sharded chunked prefill (continuous-batching serve engine)
# ---------------------------------------------------------------------------

def prefill_chunk_tp_supported(cfg, mesh, model_axis, t: int,
                               chunks: int = 1) -> bool:
    """Can one slot's prefill chunk run on the collective-matmul rings?
    The chunk's SEQUENCE dim takes the ring-row role (exactly training's
    ``overlapped_supported`` conditions, with t = the chunk length)."""
    if mesh is None or model_axis is None:
        return False
    msz = mesh.shape[model_axis]
    if msz <= 1 or not overlapped_arch_supported(cfg):
        return False
    return (cfg.n_heads > 0 and cfg.n_heads % msz == 0
            and cfg.d_ff % msz == 0 and t % msz == 0
            and (t // msz) % max(chunks, 1) == 0)


def prefill_chunk_tp(cfg, params, cache, batch, *, mesh, model_axis: str,
                     comm_chunks: int = 1, window_override=None,
                     n_valid: Optional[int] = None):
    """Chunked-prefill "extend" step for ONE slot under the tensor-MP mesh:
    the whole layer stack in one shard_map with every Megatron matmul on
    the chunked collective-matmul rings — the same schedule as training's
    ``overlapped_block_apply`` (residual stream chunk-sequence-sharded,
    qkv gather ring -> slot-mode attention against the cache -> wo reduce
    ring -> MLP rings), against the slot's extracted batch-1 cache.

    ``cache``: ``models.api.cache_extract_slot`` shape — per-layer k/v
    (Lc, 1, capacity, KV, hd) + ``pos`` (1,); batch: dict(tokens (1, t)).
    Returns (last-token logits (1, 1, V), new slot cache).

    ``n_valid`` (static, default t) marks a PADDED chunk: only the first
    ``n_valid`` tokens are real — a non-divisible final chunk padded up to
    the ring grid.  Logits are taken at position ``n_valid - 1`` and ``pos``
    advances by ``n_valid``; the pad rows written past it are inert (every
    attention mask gates on ``pos``) and get overwritten by the next
    insert at ``pos``.  Causality keeps pad keys invisible to real queries
    (pad positions are strictly later), so padding never changes the real
    tokens' math."""
    from repro.parallel.collectives import (all_gather_matmul,
                                            matmul_reduce_scatter,
                                            ring_all_gather)
    window = cfg.sliding_window if window_override is None else window_override
    tokens = batch["tokens"]
    pos = cache["pos"]
    b, t = tokens.shape
    nv = t if n_valid is None else int(n_valid)
    msz = mesh.shape[model_axis]
    t_loc = t // msz
    chunks = max(comm_chunks, 1)
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    hpm = nh // msz
    kv_sharded = nkv % msz == 0
    kvpm = nkv // msz if kv_sharded else nkv
    kw = dict(axis=model_axis, axis_size=msz, chunks=chunks)

    def local(p, layer_caches, tok, ps):
        x = _embed(cfg, p, tok)                           # (1, t, d)
        j = jax.lax.axis_index(model_axis)
        xl = jax.lax.dynamic_slice_in_dim(x, j * t_loc, t_loc, axis=1)
        clen = layer_caches["k"].shape[2]
        slot = jnp.arange(clen + t)
        in_cache = slot < clen
        qpos = ps[:, None] + jnp.arange(t)[None]          # (1, t)
        kpos = jnp.where(in_cache[None], slot[None],
                         ps[:, None] + (slot[None] - clen))
        valid = jnp.where(in_cache[None, None],
                          slot[None, None, :] < ps[:, None, None],
                          kpos[:, None, :] <= qpos[:, :, None])
        if window:
            valid &= kpos[:, None, :] > qpos[:, :, None] - window

        def body(xl, lp_cache):
            lp, csl = lp_cache
            h = L.rms_norm(xl, lp["ln1"], cfg.norm_eps)
            w_qkv = jnp.concatenate(
                [lp["attn"]["wq"], lp["attn"]["wk"], lp["attn"]["wv"]],
                axis=1).astype(xl.dtype)
            qkv = all_gather_matmul(h, w_qkv, **kw)       # (1, t, ...)
            q = qkv[..., :hpm * hd].reshape(b, t, hpm, hd)
            k = qkv[..., hpm * hd:(hpm + kvpm) * hd].reshape(b, t, kvpm, hd)
            v = qkv[..., (hpm + kvpm) * hd:].reshape(b, t, kvpm, hd)
            q = L.apply_rope(q, qpos, cfg.rope_theta)
            k = L.apply_rope(k, qpos, cfg.rope_theta)
            k_all = jnp.concatenate([csl["k"], k], axis=1)
            v_all = jnp.concatenate([csl["v"], v], axis=1)
            if kv_sharded:
                k_att, v_att = k_all, v_all
            else:
                k_att = jax.lax.dynamic_slice_in_dim(
                    L.repeat_kv(k_all, nh // nkv), j * hpm, hpm, axis=2)
                v_att = jax.lax.dynamic_slice_in_dim(
                    L.repeat_kv(v_all, nh // nkv), j * hpm, hpm, axis=2)
            out = L.attention(q, k_att, v_att, mask=valid,
                              softcap=cfg.attn_logit_softcap)
            xl = xl + matmul_reduce_scatter(
                out.reshape(b, t, hpm * hd),
                lp["attn"]["wo"].astype(xl.dtype), **kw)
            h2 = L.rms_norm(xl, lp["ln2"], cfg.norm_eps)
            xl = xl + L.mlp_apply_overlapped(lp["mlp"], h2, cfg.mlp_kind,
                                             axis=model_axis, axis_size=msz,
                                             chunks=chunks)
            kv = L.cache_insert_at({"k": csl["k"], "v": csl["v"]}, k, v, ps)
            return xl, kv

        xl, new_caches = jax.lax.scan(
            body, xl, (p["layers"], layer_caches),
            unroll=cfg.n_layers if L.analysis_unroll() else 1)
        x_full = ring_all_gather(xl, **kw)                # (1, t, d)
        logits = _head(cfg, p, x_full[:, nv - 1:nv])      # (1, 1, V)
        return logits, new_caches

    col, row = P(None, None, model_axis), P(None, model_axis, None)
    kvw = col if kv_sharded else P(None, None, None)
    p_specs = {"embed": P(None, None), "final_norm": P(None),
               "layers": {"ln1": P(None, None), "ln2": P(None, None),
                          "attn": {"wq": col, "wk": kvw, "wv": kvw,
                                   "wo": row},
                          "mlp": {k: (row if k == "wo" else col)
                                  for k in params["layers"]["mlp"]}}}
    if "lm_head" in params:
        p_specs["lm_head"] = P(None, None)
    kvm = model_axis if kv_sharded else None
    c_spec = P(None, None, None, kvm, None)
    layer_caches = {"k": cache["k"], "v": cache["v"]}
    logits, new_caches = shard_map(
        local, mesh=mesh,
        in_specs=(p_specs, {"k": c_spec, "v": c_spec},
                  P(None, None), P(None)),
        out_specs=(P(None, None, None), {"k": c_spec, "v": c_spec}))(
            params, layer_caches, tokens, pos)
    new_caches["pos"] = pos + nv
    return logits, new_caches


def prefill_chunk_cp_supported(cfg, mesh, context_axis, t: int) -> bool:
    """Can one slot's prefill chunk run context-parallel?  Mirrors
    ``cp_supported`` with t = the chunk length; no head-divisibility
    constraint — CP shards the sequence, not the heads."""
    if mesh is None or context_axis is None:
        return False
    csz = mesh.shape[context_axis]
    if csz <= 1 or not overlapped_arch_supported(cfg) \
            or cfg.attn_logit_softcap:
        return False
    return cfg.n_heads > 0 and t % csz == 0


def prefill_chunk_cp(cfg, params, cache, batch, *, mesh, context_axis: str,
                     window_override=None, n_valid: Optional[int] = None):
    """Chunked-prefill "extend" step for ONE slot with the chunk
    CONTEXT-PARALLEL: the chunk's sequence dim shards over the ring,
    in-chunk attention rides ``parallel.context.ring_attention_stats``
    (per-request absolute offsets cancel in the causal/window masks), the
    KV-cache contribution is computed locally per device against the
    replicated slot cache and merged via ``merge_softmax_stats``, and the
    chunk's new KV rows reassemble on a ``ring_all_gather`` (ppermute-only)
    for the replicated cache insert.  Weights stay fully replicated.

    Same signature/shapes as ``prefill_chunk_tp``, including the
    ``n_valid`` padded-final-chunk contract (pad tokens land on the tail
    devices of the ring and are masked/overwritten the same way)."""
    from repro.parallel.collectives import ring_all_gather
    from repro.parallel.context import ring_attention_stats
    window = cfg.sliding_window if window_override is None else window_override
    tokens = batch["tokens"]
    pos = cache["pos"]
    b, t = tokens.shape
    nv = t if n_valid is None else int(n_valid)
    csz = mesh.shape[context_axis]
    t_loc = t // csz
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    n_rep = nh // nkv
    scale = 1.0 / (hd ** 0.5)
    gkw = dict(axis=context_axis, axis_size=csz)

    def local(p, layer_caches, tok, ps):
        x = _embed(cfg, p, tok)                           # (1, t, d)
        j = jax.lax.axis_index(context_axis)
        xl = jax.lax.dynamic_slice_in_dim(x, j * t_loc, t_loc, axis=1)
        clen = layer_caches["k"].shape[2]
        slot = jnp.arange(clen)                           # cache kpos == slot
        qpos = ps[:, None] + j * t_loc + jnp.arange(t_loc)[None]  # (1, t_loc)
        valid = jnp.broadcast_to(slot[None, None, :] < ps[:, None, None],
                                 (b, t_loc, clen))
        if window:
            valid = valid & (slot[None, None, :] > qpos[:, :, None] - window)

        def body(xl, lp_cache):
            lp, csl = lp_cache
            h = L.rms_norm(xl, lp["ln1"], cfg.norm_eps)
            q = (h @ lp["attn"]["wq"].astype(h.dtype)).reshape(b, t_loc, nh, hd)
            k = (h @ lp["attn"]["wk"].astype(h.dtype)).reshape(b, t_loc, nkv, hd)
            v = (h @ lp["attn"]["wv"].astype(h.dtype)).reshape(b, t_loc, nkv, hd)
            q = L.apply_rope(q, qpos, cfg.rope_theta)
            k = L.apply_rope(k, qpos, cfg.rope_theta)
            ring_stats = ring_attention_stats(q, k, v, causal=True,
                                              window=window, **gkw)
            # cache contribution: local dense partial over the replicated
            # slot cache; a fully-masked row's bogus exp(0) probs are
            # zeroed by the merge's corr factor (m stays NEG_INF)
            kr = L.repeat_kv(csl["k"], n_rep).astype(jnp.float32)
            vr = L.repeat_kv(csl["v"], n_rep).astype(jnp.float32)
            q32 = q.astype(jnp.float32).transpose(0, 2, 1, 3) * scale
            sc = jnp.einsum("bhqd,bkhd->bhqk", q32, kr)
            sc = jnp.where(valid[:, None], sc, L.NEG_INF)
            mk = sc.max(axis=-1)
            pk = jnp.exp(sc - mk[..., None])
            cache_stats = (mk, pk.sum(axis=-1),
                           jnp.einsum("bhqk,bkhd->bhqd", pk, vr))
            m, l, acc = L.merge_softmax_stats(ring_stats, cache_stats)
            out = (acc / jnp.maximum(l, 1e-30)[..., None]
                   ).transpose(0, 2, 1, 3).astype(xl.dtype)
            xl = xl + (out.reshape(b, t_loc, nh * hd)
                       @ lp["attn"]["wo"].astype(xl.dtype))
            h2 = L.rms_norm(xl, lp["ln2"], cfg.norm_eps)
            xl = xl + L.mlp_apply(lp["mlp"], h2, cfg.mlp_kind)
            kf = ring_all_gather(k.reshape(b, t_loc, nkv * hd), **gkw
                                 ).reshape(b, t, nkv, hd)
            vf = ring_all_gather(v.reshape(b, t_loc, nkv * hd), **gkw
                                 ).reshape(b, t, nkv, hd)
            kv = L.cache_insert_at({"k": csl["k"], "v": csl["v"]}, kf, vf, ps)
            return xl, kv

        xl, new_caches = jax.lax.scan(
            body, xl, (p["layers"], layer_caches),
            unroll=cfg.n_layers if L.analysis_unroll() else 1)
        x_full = ring_all_gather(xl, **gkw)               # (1, t, d)
        logits = _head(cfg, p, x_full[:, nv - 1:nv])      # (1, 1, V)
        return logits, new_caches

    p_specs = jax.tree.map(lambda a: P(*(None,) * jnp.ndim(a)), params)
    c_spec = P(None, None, None, None, None)
    layer_caches = {"k": cache["k"], "v": cache["v"]}
    logits, new_caches = shard_map(
        local, mesh=mesh,
        in_specs=(p_specs, {"k": c_spec, "v": c_spec},
                  P(None, None), P(None)),
        out_specs=(P(None, None, None), {"k": c_spec, "v": c_spec}))(
            params, layer_caches, tokens, pos)
    new_caches["pos"] = pos + nv
    return logits, new_caches
