"""Mixture-of-Experts FFN with capacity-bounded, sort-based dispatch.

Expert parallelism ("EP") maps onto the production mesh's ``model`` axis: each
model shard owns ``E / mp`` experts; activations are replicated across the
model axis (they are data-sharded on ``data``), every shard computes only the
tokens routed to *its* experts via a sorted capacity buffer, and one
``psum`` over the model axis combines contributions — the same collective
footprint as a Megatron TP MLP, with balanced FLOPs in expectation.

Dispatch is MegaBlocks-style: flatten (token, k) assignments, rank tokens
within their expert by a sorted running count, and gather them into a dense
``(E_local, capacity, d)`` buffer so the expert matmuls are fixed-shape MXU
einsums.  Tokens beyond capacity are dropped (standard top-k MoE semantics);
tests use ``capacity_factor`` high enough for zero drops and compare against
the dense all-experts oracle.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def moe_init(key, cfg, dtype=jnp.float32):
    d, e, ff = cfg.d_model, cfg.n_experts, cfg.expert_d_ff
    ks = jax.random.split(key, 5)
    params = {
        "router": dense_init(ks[0], d, e, jnp.float32),  # router kept f32
        "wi": (jax.random.normal(ks[1], (e, d, ff)) / math.sqrt(d)).astype(dtype),
        "wg": (jax.random.normal(ks[2], (e, d, ff)) / math.sqrt(d)).astype(dtype),
        "wo": (jax.random.normal(ks[3], (e, ff, d)) / math.sqrt(ff)).astype(dtype),
    }
    if cfg.n_shared_experts:
        sff = cfg.expert_d_ff * cfg.n_shared_experts
        sk = jax.random.split(ks[4], 3)
        params["shared"] = {
            "wi": dense_init(sk[0], d, sff, dtype),
            "wg": dense_init(sk[1], d, sff, dtype),
            "wo": dense_init(sk[2], sff, d, dtype),
        }
    return params


def _route(router_w, xf, n_experts: int, k: int):
    """Top-k routing.  Returns (ids (t,k), weights (t,k), aux_loss)."""
    logits = (xf.astype(jnp.float32) @ router_w)                 # (t, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, k)                              # (t, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: E * sum_e f_e * p_e
    f = jnp.zeros((n_experts,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    f = f / jnp.maximum(f.sum(), 1.0)
    p = probs.mean(0)
    aux = n_experts * jnp.sum(f * p)
    return ids, w.astype(xf.dtype), aux


def _expert_compute(xf, ids, w, wi, wg, wo, lo: int, cap: int):
    """Compute routed-expert output for experts [lo, lo + E_local).

    xf: (t, d); ids/w: (t, k); wi/wg: (E_local, d, ff); wo: (E_local, ff, d).
    Returns partial (t, d) containing only local experts' contributions.
    """
    t, d = xf.shape
    k = ids.shape[1]
    e_loc = wi.shape[0]
    flat_ids = ids.reshape(-1)                                    # (t*k,)
    flat_w = w.reshape(-1)
    local = (flat_ids >= lo) & (flat_ids < lo + e_loc)
    local_ids = jnp.where(local, flat_ids - lo, e_loc)            # sentinel e_loc
    # rank within expert group, computed on sorted order
    order = jnp.argsort(local_ids)                                # stable
    sorted_ids = local_ids[order]
    counts = jnp.zeros((e_loc + 1,), jnp.int32).at[local_ids].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    rank_sorted = jnp.arange(t * k, dtype=jnp.int32) - starts[sorted_ids]
    rank = jnp.zeros((t * k,), jnp.int32).at[order].set(rank_sorted)
    keep = local & (rank < cap)
    slot = jnp.where(keep, sorted_slot := local_ids * cap + rank, e_loc * cap)
    # scatter token rows into the capacity buffer (extra row = drop bin)
    tok_idx = jnp.arange(t * k, dtype=jnp.int32) // k
    buf_tok = jnp.full((e_loc * cap + 1,), t, jnp.int32).at[slot].set(
        jnp.where(keep, tok_idx, t))
    buf_tok = buf_tok[:-1]                                        # (e_loc*cap,)
    xpad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], 0)
    xb = xpad[buf_tok].reshape(e_loc, cap, d)
    # expert FFN (swiglu), fixed-shape einsums
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xb, wg.astype(xf.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", xb, wi.astype(xf.dtype))
    y = jnp.einsum("ecf,efd->ecd", h, wo.astype(xf.dtype)).reshape(e_loc * cap, d)
    # combine back, weighted
    wpad = jnp.concatenate([flat_w, jnp.zeros((1,), xf.dtype)])
    slot_of_flat = jnp.where(keep, slot, e_loc * cap)
    ypad = jnp.concatenate([y, jnp.zeros((1, d), xf.dtype)], 0)
    contrib = ypad[slot_of_flat] * wpad[jnp.where(keep, jnp.arange(t * k), t * k)][:, None]
    out = jnp.zeros((t, d), xf.dtype).at[tok_idx].add(
        jnp.where(keep[:, None], contrib, 0))
    return out


def _shared_expert(params, x):
    h = jax.nn.silu(x @ params["wg"].astype(x.dtype)) * (x @ params["wi"].astype(x.dtype))
    return h @ params["wo"].astype(x.dtype)


def moe_ffn(params, x, cfg, *, model_axis: Optional[str] = None,
            ff_axes=None, capacity_factor: Optional[float] = 1.25):
    """MoE FFN.  x: (B, S, d) -> (out (B, S, d), aux_loss scalar).

    ``model_axis`` is set when called inside ``shard_map`` — expert weights
    arrive pre-sliced to the local shard and the combine psums over that axis.
    ``ff_axes`` (decode-path 2D expert sharding, §Perf iteration B): the
    per-expert hidden dim arrives additionally sliced over these mesh axes;
    valid only when tokens are REPLICATED across them (batch=1 decode), and
    the final psum then spans (model_axis,) + ff_axes.  Outside shard_map
    (mp=1 smoke tests) all experts are local.
    """
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    t = b * s
    k = cfg.experts_per_token
    ids, w, aux = _route(params["router"], xf, cfg.n_experts, k)
    if capacity_factor is None:
        cap = t          # no-drop: an expert can receive every token at most once
    else:
        cap = max(1, math.ceil(t * k / cfg.n_experts * capacity_factor))
    if model_axis is None:
        lo = 0
    else:
        e_loc = params["wi"].shape[0]
        lo = jax.lax.axis_index(model_axis) * e_loc
    out = _expert_compute(xf, ids, w, params["wi"], params["wg"], params["wo"],
                          lo, cap)
    if "shared" in params:
        # shared experts: d_ff sharded over the model axis when inside
        # shard_map (weights arrive pre-sliced), partial-summed by the same psum
        out = out + _shared_expert(params["shared"], xf)
    if model_axis is not None:
        axes = (model_axis,) + tuple(ff_axes or ())
        # reduce in the activation dtype: XLA upcasts the combine scatter-add
        # to f32, and psum-ing that doubles EP wire bytes (§Perf iteration C.1)
        out = jax.lax.psum(out.astype(x.dtype), axes)
        aux = jax.lax.pmean(aux, model_axis)
    return out.reshape(b, s, d), aux


def moe_ffn_dense_oracle(params, x, cfg):
    """Reference: every expert computes every token; combine by router weights."""
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    ids, w, aux = _route(params["router"], xf, cfg.n_experts, cfg.experts_per_token)
    h = jax.nn.silu(jnp.einsum("td,edf->etf", xf, params["wg"].astype(xf.dtype)))
    h = h * jnp.einsum("td,edf->etf", xf, params["wi"].astype(xf.dtype))
    y = jnp.einsum("etf,efd->etd", h, params["wo"].astype(xf.dtype))   # (E,t,d)
    comb = jnp.zeros((xf.shape[0], cfg.n_experts), xf.dtype)
    comb = comb.at[jnp.arange(xf.shape[0])[:, None], ids].set(w)
    out = jnp.einsum("te,etd->td", comb, y)
    if "shared" in params:
        out = out + _shared_expert(params["shared"], xf)
    return out.reshape(b, s, d), aux
