"""Data pipeline: deterministic synthetic datasets + sharded host feed."""
from repro.data.synthetic import (
    MarkovLM,
    SyntheticImageDataset,
    SyntheticSeq2Seq,
    make_lm_dataset,
)
from repro.data.pipeline import DataPipeline, shard_batch

__all__ = ["MarkovLM", "SyntheticImageDataset", "SyntheticSeq2Seq",
           "make_lm_dataset", "DataPipeline", "shard_batch"]
