"""Host-side data pipeline: batching, device placement, prefetch.

``DataPipeline`` wraps an epoch-iterator dataset and feeds sharded device
batches (placing each host batch with the batch NamedShardings so pjit never
re-lays-out inputs); one-deep prefetch overlaps host generation with device
compute — enough for the synthetic datasets here while keeping the structure
of a production loader.
"""
from __future__ import annotations

import collections
import threading
from typing import Callable, Iterator, Optional

import jax
import numpy as np


def shard_batch(batch: dict, shardings: Optional[dict] = None) -> dict:
    if shardings is None:
        return jax.tree.map(jax.numpy.asarray, batch)
    return jax.tree.map(lambda x, s: jax.device_put(np.asarray(x), s),
                        batch, shardings)


class DataPipeline:
    def __init__(self, epoch_fn: Callable[[int], Iterator[dict]],
                 shardings: Optional[dict] = None, prefetch: int = 1):
        self.epoch_fn = epoch_fn
        self.shardings = shardings
        self.prefetch = prefetch

    def epoch(self, epoch_idx: int) -> Iterator[dict]:
        it = self.epoch_fn(epoch_idx)
        if self.prefetch <= 0:
            for b in it:
                yield shard_batch(b, self.shardings)
            return
        q: collections.deque = collections.deque()
        done = object()

        def fill():
            for b in it:
                while len(q) > self.prefetch:
                    ev.wait(0.001)
                q.append(shard_batch(b, self.shardings))
            q.append(done)

        ev = threading.Event()
        t = threading.Thread(target=fill, daemon=True)
        t.start()
        while True:
            if not q:
                ev.wait(0.0005)
                ev.clear()
                continue
            item = q.popleft()
            if item is done:
                return
            yield item
