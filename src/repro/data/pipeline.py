"""Host-side data pipeline: batching, device placement, prefetch, resume.

``DataPipeline`` wraps an epoch-iterator dataset and feeds sharded device
batches (placing each host batch with the batch NamedShardings so pjit never
re-lays-out inputs); one-deep prefetch overlaps host generation with device
compute — enough for the synthetic datasets here while keeping the structure
of a production loader.

Exact-order resume: ``epoch(e, skip=n)`` drops the first ``n`` *host*
batches of epoch ``e`` before any device placement, so a training run
resuming at global step ``s`` consumes exactly the batches an uninterrupted
run would have seen from step ``s`` on — no sample replayed, none dropped.
``steps_per_epoch`` (when the dataset knows it) lets the resuming loop jump
straight to ``(s // steps_per_epoch, s % steps_per_epoch)``; otherwise
``count_epoch`` walks an epoch host-side so the loop can locate ``s``.
"""
from __future__ import annotations

import collections
import itertools
import threading
from typing import Callable, Iterator, Optional

import jax
import numpy as np


def shard_batch(batch: dict, shardings: Optional[dict] = None) -> dict:
    if shardings is None:
        return jax.tree.map(jax.numpy.asarray, batch)
    return jax.tree.map(lambda x, s: jax.device_put(np.asarray(x), s),
                        batch, shardings)


class DataPipeline:
    def __init__(self, epoch_fn: Callable[[int], Iterator[dict]],
                 shardings: Optional[dict] = None, prefetch: int = 1,
                 steps_per_epoch: Optional[int] = None):
        self.epoch_fn = epoch_fn
        self.shardings = shardings
        self.prefetch = prefetch
        self.steps_per_epoch = steps_per_epoch

    def count_epoch(self, epoch_idx: int) -> int:
        """Number of batches epoch ``epoch_idx`` yields (host-side walk; used
        by resume when ``steps_per_epoch`` is unknown)."""
        if self.steps_per_epoch is not None:
            return self.steps_per_epoch
        return sum(1 for _ in self.epoch_fn(epoch_idx))

    def locate(self, global_step: int):
        """(epoch, batches-to-skip) positioning ``global_step`` in the
        epoch stream — the exact-data-order resume arithmetic."""
        if global_step <= 0:
            return 0, 0
        if self.steps_per_epoch:
            return divmod(global_step, self.steps_per_epoch)
        epoch, remaining = 0, global_step
        while True:
            n = self.count_epoch(epoch)
            if n <= 0:
                raise RuntimeError(
                    f"cannot locate step {global_step} for resume: epoch "
                    f"{epoch} yields no batches (after skipping "
                    f"{global_step - remaining})")
            if remaining < n:
                return epoch, remaining
            remaining -= n
            epoch += 1

    def epoch(self, epoch_idx: int, skip: int = 0) -> Iterator[dict]:
        it = self.epoch_fn(epoch_idx)
        if skip:
            it = itertools.islice(it, skip, None)
        if self.prefetch <= 0:
            for b in it:
                yield shard_batch(b, self.shardings)
            return
        q: collections.deque = collections.deque()
        done = object()

        def fill():
            for b in it:
                while len(q) > self.prefetch:
                    ev.wait(0.001)
                q.append(shard_batch(b, self.shardings))
            q.append(done)

        ev = threading.Event()
        t = threading.Thread(target=fill, daemon=True)
        t.start()
        while True:
            if not q:
                ev.wait(0.0005)
                ev.clear()
                continue
            item = q.popleft()
            if item is done:
                return
            yield item
