"""Deterministic synthetic-but-learnable datasets.

The fig4 statistical-efficiency experiments need a task with a real loss
floor and a meaningful "epochs to converge" — a fixed-seed order-2 Markov
chain LM provides both: the optimal loss is its conditional entropy, and a
model must actually learn the transition table to reach it.  Epoch semantics
(a finite dataset iterated in a shuffled order) follow the paper's setup.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np


@dataclasses.dataclass
class MarkovLM:
    """Order-2 Markov chain over `vocab` symbols; dataset of `n_items`
    sequences of `seq_len` tokens."""

    vocab: int = 64
    seq_len: int = 64
    n_items: int = 4096
    seed: int = 0
    temperature: float = 1.2

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        logits = rng.normal(size=(self.vocab, self.vocab, self.vocab)) \
            * self.temperature
        self.trans = np.exp(logits)
        self.trans /= self.trans.sum(-1, keepdims=True)
        self._data = self._generate(rng)

    def _generate(self, rng) -> np.ndarray:
        n, t, v = self.n_items, self.seq_len + 1, self.vocab
        seqs = np.zeros((n, t), dtype=np.int32)
        seqs[:, 0] = rng.integers(0, v, n)
        seqs[:, 1] = rng.integers(0, v, n)
        for i in range(2, t):
            p = self.trans[seqs[:, i - 2], seqs[:, i - 1]]
            cum = p.cumsum(-1)
            u = rng.random((n, 1))
            seqs[:, i] = (u > cum).sum(-1)
        return seqs

    @property
    def entropy(self) -> float:
        """Conditional entropy = the optimal achievable loss (nats/token)."""
        h = -(self.trans * np.log(self.trans + 1e-12)).sum(-1)
        return float(h.mean())

    def epoch(self, epoch_idx: int, global_batch: int) -> Iterator[dict]:
        rng = np.random.default_rng(self.seed + 1000 + epoch_idx)
        order = rng.permutation(self.n_items)
        for i in range(0, self.n_items - global_batch + 1, global_batch):
            idx = order[i:i + global_batch]
            seqs = self._data[idx]
            yield {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}

    def steps_per_epoch(self, global_batch: int) -> int:
        return self.n_items // global_batch


@dataclasses.dataclass
class SyntheticSeq2Seq:
    """Learnable copy-with-vocab-map task for GNMT-style models."""

    vocab: int = 64
    seq_len: int = 24
    n_items: int = 2048
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.perm = rng.permutation(self.vocab)
        self.src = rng.integers(2, self.vocab, (self.n_items, self.seq_len),
                                dtype=np.int32)
        self.tgt = self.perm[self.src].astype(np.int32)

    def epoch(self, epoch_idx: int, global_batch: int) -> Iterator[dict]:
        rng = np.random.default_rng(self.seed + 1000 + epoch_idx)
        order = rng.permutation(self.n_items)
        for i in range(0, self.n_items - global_batch + 1, global_batch):
            idx = order[i:i + global_batch]
            tgt_in = np.concatenate(
                [np.ones((len(idx), 1), np.int32), self.tgt[idx][:, :-1]], 1)
            yield {"src": self.src[idx], "tgt": tgt_in,
                   "labels": self.tgt[idx]}


@dataclasses.dataclass
class SyntheticImageDataset:
    """Class-conditional Gaussian blobs for the Inception-V3 convergence runs."""

    n_classes: int = 16
    image_size: int = 64
    n_items: int = 2048
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.protos = rng.normal(size=(self.n_classes, 8, 8, 3)).astype(np.float32)
        self.labels = rng.integers(0, self.n_classes, self.n_items).astype(np.int32)

    def _images(self, idx, rng) -> np.ndarray:
        base = self.protos[self.labels[idx]]
        up = np.repeat(np.repeat(base, self.image_size // 8, 1),
                       self.image_size // 8, 2)
        noise = rng.normal(
            scale=0.7, size=(len(idx), self.image_size, self.image_size, 3))
        return (up + noise).astype(np.float32)

    def epoch(self, epoch_idx: int, global_batch: int) -> Iterator[dict]:
        rng = np.random.default_rng(self.seed + 1000 + epoch_idx)
        order = rng.permutation(self.n_items)
        for i in range(0, self.n_items - global_batch + 1, global_batch):
            idx = order[i:i + global_batch]
            yield {"images": self._images(idx, rng),
                   "labels": self.labels[idx]}


def make_lm_dataset(vocab: int = 64, seq_len: int = 64, n_items: int = 4096,
                    seed: int = 0) -> MarkovLM:
    return MarkovLM(vocab=vocab, seq_len=seq_len, n_items=n_items, seed=seed)
