"""Optimizers and LR schedules (optax-like minimal API, pure JAX).

Includes the paper's training setups: momentum-SGD with the linear
batch-size/LR scaling rule (Goyal et al., used for Inception-V3), exponential
warmup + step decay (GNMT), and AdamW / Adafactor for the modern archs —
Adafactor's factored second moment is what lets the 1T-param MoE fit the
per-device HBM budget (DESIGN.md §4).
"""
from repro.optim.optimizers import (
    Optimizer,
    adafactor,
    adamw,
    momentum_sgd,
    sgd,
    apply_updates,
)
from repro.optim.schedules import (
    constant_lr,
    cosine_decay,
    exp_warmup_step_decay,
    linear_scaled_lr,
    warmup_cosine,
)

__all__ = [
    "Optimizer", "adafactor", "adamw", "momentum_sgd", "sgd", "apply_updates",
    "constant_lr", "cosine_decay", "exp_warmup_step_decay", "linear_scaled_lr",
    "warmup_cosine",
]
