"""Minimal optimizer implementations with a two-function API:

    opt = adamw(lr_schedule, ...)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params, step)
    params = apply_updates(params, updates)

States are pytrees mirroring params, so they inherit the params'
NamedShardings under pjit (ZeRO-1 falls out of fsdp param sharding).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable          # (grads, state, params, step) -> (updates, state)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
                        params, updates)


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def sgd(lr: Callable) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params, step):
        lr_t = lr(step)
        return jax.tree.map(lambda g: -lr_t * g.astype(jnp.float32), grads), state

    return Optimizer(init, update)


def momentum_sgd(lr: Callable, momentum: float = 0.9,
                 dtype=jnp.float32) -> Optimizer:
    """The paper's CNN/LSTM optimizer.  Momentum kept in ``dtype`` (bf16 option
    halves optimizer memory for the giant archs)."""

    def init(params):
        return {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, dtype), params)}

    def update(grads, state, params, step):
        m = jax.tree.map(lambda m_, g: momentum * m_.astype(jnp.float32)
                         + g.astype(jnp.float32), state["m"], grads)
        lr_t = lr(step)
        upd = jax.tree.map(lambda m_: -lr_t * m_, m)
        return upd, {"m": jax.tree.map(lambda x: x.astype(dtype), m)}

    return Optimizer(init, update)


def adamw(lr: Callable, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2)
                         * jnp.square(g.astype(jnp.float32)), state["v"], grads)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t
        lr_t = lr(step)

        def upd(m_, v_, p):
            u = -lr_t * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u

        return jax.tree.map(upd, m, v, params), {"m": m, "v": v}

    return Optimizer(init, update)


def adafactor(lr: Callable, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0) -> Optimizer:
    """Factored second-moment optimizer (Shazeer & Stern 2018).

    For a (.., r, c) weight, keeps only row/col second-moment accumulators —
    O(r + c) instead of O(r*c) state, the fit-enabler for kimi-k2 (DESIGN §4).
    """

    def init(params):
        def z(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {"acc": jax.tree.map(z, params,
                                    is_leaf=lambda x: hasattr(x, "ndim"))}

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        beta = 1.0 - t ** (-decay)
        lr_t = lr(step)

        def upd(g, acc):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if g.ndim >= 2:
                vr = beta * acc["vr"] + (1 - beta) * g2.mean(-1)
                vc = beta * acc["vc"] + (1 - beta) * g2.mean(-2)
                denom = jnp.sqrt(vr[..., None] * vc[..., None, :]
                                 / jnp.maximum(vr.mean(-1, keepdims=True)[..., None], eps))
                u = g / jnp.maximum(denom, eps)
                new = {"vr": vr, "vc": vc}
            else:
                v = beta * acc["v"] + (1 - beta) * g2
                u = g / jnp.sqrt(v + eps)
                new = {"v": v}
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return -lr_t * u, new

        flat_g, tree = jax.tree.flatten(grads)
        flat_a = tree.flatten_up_to(state["acc"])
        outs = [upd(g, a) for g, a in zip(flat_g, flat_a)]
        updates = tree.unflatten([o[0] for o in outs])
        acc = tree.unflatten([o[1] for o in outs])
        return updates, {"acc": acc}

    return Optimizer(init, update)


OPTIMIZERS = {
    "sgd": sgd,
    "momentum": momentum_sgd,
    "adamw": adamw,
    "adafactor": adafactor,
}
