"""LR schedules, including the paper's exact recipes (§4):

- Inception-V3: initial LR scaled linearly with global batch (Goyal et al.).
- GNMT: exponential warmup for 200 steps; decay x0.5 every 500 steps starting
  at step 6000, four decays total.
- plus warmup-cosine for the modern archs.
"""
from __future__ import annotations

import jax.numpy as jnp


def constant_lr(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_scaled_lr(base_lr: float, base_batch: int, global_batch: int,
                     warmup_steps: int = 500):
    """Goyal et al. linear scaling rule with gradual warmup."""
    peak = base_lr * global_batch / base_batch

    def sched(step):
        s = jnp.asarray(step, jnp.float32)
        warm = peak * (s + 1) / max(warmup_steps, 1)
        return jnp.minimum(warm, peak)

    return sched


def exp_warmup_step_decay(peak_lr: float, warmup_steps: int = 200,
                          decay_start: int = 6000, decay_interval: int = 500,
                          decay_factor: float = 0.5, n_decays: int = 4):
    """The paper's GNMT schedule."""

    def sched(step):
        s = jnp.asarray(step, jnp.float32)
        warm = peak_lr * jnp.exp(jnp.minimum(s / warmup_steps, 1.0) - 1.0) \
            / jnp.exp(0.0)
        warm = peak_lr * jnp.exp((jnp.minimum(s, warmup_steps) / warmup_steps - 1.0) * 4.0)
        n_dec = jnp.clip(jnp.floor((s - decay_start) / decay_interval) + 1,
                         0, n_decays)
        return jnp.where(s < warmup_steps, warm,
                         peak_lr * decay_factor ** n_dec)

    return sched


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def sched(step):
        s = jnp.asarray(step, jnp.float32)
        warm = peak_lr * (s + 1) / max(warmup_steps, 1)
        prog = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1),
                        0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup_steps, warm, peak_lr * cos)

    return sched


def cosine_decay(peak_lr: float, total_steps: int, final_frac: float = 0.0):
    return warmup_cosine(peak_lr, 0, total_steps, final_frac)
