"""repro: hybrid data/model-parallel JAX training framework reproducing
Pal et al. 2019, "Optimizing Multi-GPU Parallelization Strategies for Deep
Learning Training" (IEEE Micro), adapted to multi-pod TPU."""

__version__ = "1.0.0"
