"""Config system: architecture configs, input shapes, and the registry.

Every assigned architecture gets one ``src/repro/configs/<id>.py`` exporting
``CONFIG``; the registry maps ``--arch <id>`` to it.  ``reduced()`` yields the
CPU smoke-test variant (2 layers, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

# The model axis of the production mesh; dims not divisible by this are
# replicated (see parallel/sharding.py) and vocabs are padded to a multiple of
# VOCAB_PAD_TO so the output projection always shards.
MODEL_AXIS_SIZE = 16
VOCAB_PAD_TO = 256


def pad_vocab(v: int, multiple: int = VOCAB_PAD_TO) -> int:
    return ((v + multiple - 1) // multiple) * multiple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description.  One instance per assigned arch."""

    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio | cnn | rnn
    n_layers: int
    d_model: int
    n_heads: int                     # 0 => attention-free
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    source: str = ""                 # citation: paper/model-card

    # --- attention ---
    head_dim: int = 0                # derived if 0
    rope_theta: float = 10000.0
    sliding_window: int = 0          # 0 => full attention (arch as published)
    long_context_window: int = 8192  # window used for the long_500k variant
    attn_logit_softcap: float = 0.0

    # --- MLP ---
    mlp_kind: str = "swiglu"         # swiglu | gelu | sqrelu
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0                # per-expert hidden size (d_ff used if 0)
    n_shared_experts: int = 0
    router_aux_loss: float = 0.01

    # --- SSM / hybrid (mamba-style) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2

    # --- RWKV ---
    rwkv: bool = False

    # --- encoder-decoder / multimodal stub frontend ---
    encoder_layers: int = 0          # >0 => enc-dec (whisper)
    encoder_seq: int = 0             # frames/patches produced by the stub frontend
    frontend: str = ""               # "audio-conv-stub" | "vit-patch-stub" | ""
    n_prefix_embeds: int = 0         # VLM: patch embeds prepended to the text sequence

    # --- numerics ---
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    norm_eps: float = 1e-5

    def __post_init__(self):
        if self.n_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ---- derived ----
    @property
    def vocab_padded(self) -> int:
        return pad_vocab(self.vocab_size)

    @property
    def attention_free(self) -> bool:
        return self.n_heads == 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    def n_params(self) -> int:
        """Approximate parameter count (used by the analytical model)."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab_padded * d * (1 if self.tie_embeddings else 2)
        if self.rwkv:
            per_layer = 4 * d * d + 3 * d * self.d_ff  # time-mix + channel-mix
        else:
            hd = self.head_dim
            attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + (self.n_heads * hd) * d
            if self.is_moe:
                mlp_mult = 3 if self.mlp_kind == "swiglu" else 2
                mlp = self.n_experts * mlp_mult * d * self.expert_d_ff
                mlp += d * self.n_experts  # router
                mlp += self.n_shared_experts * mlp_mult * d * self.expert_d_ff
            else:
                mlp_mult = 3 if self.mlp_kind == "swiglu" else 2
                mlp = mlp_mult * d * self.d_ff
            ssm = 0
            if self.ssm_state:
                di = self.ssm_expand * d
                ssm = 2 * d * di + di * self.ssm_conv + di * (2 * self.ssm_state + 1) + di * d
            per_layer = (attn if self.n_heads else 0) + mlp + ssm
        enc = 0
        if self.encoder_layers:
            hd = self.head_dim
            enc_attn = 4 * d * d
            enc_mlp = 2 * d * self.d_ff
            enc = self.encoder_layers * (enc_attn + enc_mlp)
            per_layer += 2 * d * d + 2 * d * (self.n_kv_heads * hd)  # cross-attn
        return emb + L * per_layer + enc

    def n_active_params(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if not self.is_moe:
            return self.n_params()
        full = self.n_params()
        mlp_mult = 3 if self.mlp_kind == "swiglu" else 2
        all_exp = self.n_layers * self.n_experts * mlp_mult * self.d_model * self.expert_d_ff
        act_exp = self.n_layers * self.experts_per_token * mlp_mult * self.d_model * self.expert_d_ff
        return full - all_exp + act_exp

    def reduced(self) -> "ModelConfig":
        """CPU smoke-test variant of the same family."""
        d = min(self.d_model, 256)
        heads = 0
        kv = 0
        if self.n_heads:
            heads = min(self.n_heads, 4)
            kv = max(1, min(self.n_kv_heads, heads))
            while heads % kv:
                kv -= 1
        return dataclasses.replace(
            self,
            n_layers=2,
            d_model=d,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=(d // heads if heads else 0),
            d_ff=min(self.d_ff, 512),
            moe_d_ff=min(self.expert_d_ff, 256) if self.is_moe else 0,
            vocab_size=min(self.vocab_size, 1024),
            n_experts=min(self.n_experts, 4) if self.is_moe else 0,
            experts_per_token=min(self.experts_per_token, 2) if self.is_moe else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            encoder_layers=min(self.encoder_layers, 2) if self.encoder_layers else 0,
            encoder_seq=min(self.encoder_seq, 16) if self.encoder_seq else 0,
            n_prefix_embeds=min(self.n_prefix_embeds, 8) if self.n_prefix_embeds else 0,
            dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One of the assigned (seq_len, global_batch, kind) points."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "internvl2_2b",
    "granite_moe_1b_a400m",
    "kimi_k2_1t_a32b",
    "stablelm_12b",
    "smollm_360m",
    "llama3_2_1b",
    "hymba_1_5b",
    "rwkv6_7b",
    "nemotron_4_340b",
    "whisper_large_v3",
]
PAPER_IDS = ["inception_v3", "gnmt", "biglstm"]


def get_config(arch: str) -> ModelConfig:
    arch = arch.replace("-", "_").replace(".", "_")
    if arch not in ARCH_IDS + PAPER_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS + PAPER_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def all_configs() -> dict:
    return {a: get_config(a) for a in ARCH_IDS}
