"""Whisper large-v3 — enc-dec, conv frontend stubbed [arXiv:2212.04356]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, d_ff=5120,
    vocab_size=51866, mlp_kind="gelu",
    encoder_layers=32, encoder_seq=1500, frontend="audio-conv-stub",
    source="enc-dec, conv frontend (stub) [arXiv:2212.04356]",
)
