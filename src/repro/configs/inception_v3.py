"""Inception-V3 — the paper's CNN evaluation model [Szegedy et al. 2015]."""
from repro.configs.base import ModelConfig

# CNN family: d_model/d_ff unused by the transformer stack; the Inception model
# definition (models/inception.py) reads its own block table.  vocab_size is the
# number of ImageNet classes.
CONFIG = ModelConfig(
    name="inception-v3", family="cnn",
    n_layers=11, d_model=2048, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab_size=1000, source="paper eval model [arXiv:1512.00567]",
)
