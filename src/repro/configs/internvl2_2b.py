"""InternVL2-2B language backbone (InternViT frontend stubbed) [arXiv:2404.16821]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, d_ff=8192,
    vocab_size=92553, source="InternVL2 — InternViT + InternLM2 [arXiv:2404.16821]",
    frontend="vit-patch-stub", n_prefix_embeds=256,
)
