"""RWKV-6 (Finch) 7B — attention-free, data-dependent decay [arXiv:2404.05892]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=0, n_kv_heads=0, d_ff=14336,
    vocab_size=65536, rwkv=True, head_dim=64,
    source="Finch — data-dependent decay [arXiv:2404.05892]",
)
