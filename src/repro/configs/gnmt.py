"""GNMT — the paper's seq2seq evaluation model [Wu et al. 2016].

4 LSTM layers of size 1024 in encoder and decoder, attention."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gnmt", family="rnn",
    n_layers=4, d_model=1024, n_heads=1, n_kv_heads=1, d_ff=1024,
    vocab_size=32000, encoder_layers=4,
    source="paper eval model [arXiv:1609.08144], NVIDIA GNMTv2 impl",
)
