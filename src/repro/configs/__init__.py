"""Config registry: one module per assigned architecture + the paper's own models."""
from repro.configs.base import (
    ARCH_IDS,
    INPUT_SHAPES,
    PAPER_IDS,
    InputShape,
    ModelConfig,
    all_configs,
    get_config,
)

__all__ = [
    "ARCH_IDS", "PAPER_IDS", "INPUT_SHAPES", "InputShape", "ModelConfig",
    "all_configs", "get_config",
]
