"""BigLSTM — the paper's language-model evaluation [Jozefowicz et al. 2016].

Embedding 1024, 2 LSTM layers hidden 8192 with 1024 projection, softmax."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="biglstm", family="rnn",
    n_layers=2, d_model=1024, n_heads=0, n_kv_heads=0, d_ff=8192,
    vocab_size=793472, source="paper eval model [arXiv:1602.02410]",
)
