"""Nemotron-4 340B — GQA, squared-ReLU [arXiv:2402.16819]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b", family="dense",
    n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8, d_ff=73728,
    vocab_size=256000, mlp_kind="sqrelu",
    source="GQA, squared-ReLU [arXiv:2402.16819]",
)
