"""Serving engines: static-batch generate loop + continuous batching.

Two tiers:

``ServeEngine`` (this module) — static request batching: one jitted prefill,
one jitted decode step reused across tokens, KV/state cache threaded
functionally.  Supports ragged prompt batches (``prompt_lens`` — per-request
first-token gather + per-request cache positions), EOS/stop-token early
exit with per-request lengths, and an engine-level PRNG counter so keyless
temperature sampling differs across calls.  The decode_32k / long_500k
dry-run shapes lower exactly the ``decode_step`` this engine calls per token.

``ContinuousEngine`` (``serve.continuous``) — the real serving path: a
slotted KV cache (``models.api.make_slot_cache``) where requests are
admitted into free slots mid-flight, chunked prefill interleaves with decode
ticks so long prompts never stall the running batch, finished requests are
evicted and their slots reused, and the decode tick can execute under a
dp x tp mesh on the overlap-scheduled collective-matmul rings
(``transformer.decode_slots_tp``).  The admission/slot model is documented
there; the latency-SLO-constrained plan search lives in
``core.planner.HybridPlanner.best_inference``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.models.api import ModelApi


@dataclasses.dataclass
class GenerationResult:
    tokens: jnp.ndarray          # (B, max_new) — pad_id past each row's length
    logprobs: jnp.ndarray        # (B, max_new) — 0.0 past each row's length
    prefill_len: int
    lengths: Optional[jnp.ndarray] = None   # (B,) generated tokens per row,
                                            # stop token included


def _slot_capable(cfg) -> bool:
    """Archs whose cache admits per-request positions (linear KV, no
    recurrent/cross-attn state) — the gate for ``prompt_lens`` here and for
    the slotted continuous engine."""
    return not (cfg.rwkv or cfg.family == "hybrid" or cfg.encoder_layers
                or cfg.n_prefix_embeds)


class ServeEngine:
    def __init__(self, api: ModelApi, params, *, pctx=None, window=None,
                 temperature: float = 0.0, seed: int = 0):
        self.api = api
        self.params = params
        self.pctx = pctx
        self.window = window
        self.temperature = temperature
        # engine-level PRNG stream: keyless generate() calls fold a call
        # counter into this, so repeated sampling calls differ unless the
        # caller pins an explicit key
        self._base_key = jax.random.PRNGKey(seed)
        self._n_calls = 0
        self._decode = jax.jit(
            lambda p, cache, batch: api.decode_fn(p, cache, batch, pctx,
                                                  window=window))

    def generate(self, prompt_batch: dict, *, max_new_tokens: int,
                 capacity: Optional[int] = None,
                 key: Optional[jax.Array] = None,
                 eos_id: Optional[int] = None,
                 stop_tokens: Sequence[int] = (),
                 prompt_lens=None) -> GenerationResult:
        """prompt_batch: dict(tokens (B, S) [, prefix/frames]).

        Greedy when temperature == 0, else temperature sampling.  Rows that
        emit ``eos_id`` / any of ``stop_tokens`` are frozen (pad tokens,
        0.0 logprobs) and the loop exits early once every row is finished;
        ``GenerationResult.lengths`` reports per-row generated counts (stop
        token included).  ``prompt_lens`` (B,) marks the valid prefix of
        each left-aligned row in a ragged batch: the first token is sampled
        from position ``len - 1`` (not the padded tail) and each row decodes
        from its own cache position.
        """
        tokens = prompt_batch["tokens"]
        b, s = tokens.shape
        cfg = self.api.cfg
        cap = (s + max_new_tokens + 8) if capacity is None else capacity
        window = cfg.sliding_window if self.window is None else self.window
        if not cfg.rwkv and not window and cap < s + max_new_tokens:
            raise ValueError(
                f"KV cache capacity {cap} cannot hold prompt ({s}) + "
                f"max_new_tokens ({max_new_tokens}) = {s + max_new_tokens} "
                f"positions for {cfg.name}; pass capacity >= "
                f"{s + max_new_tokens} (or omit it)")
        if prompt_lens is not None:
            prompt_lens = jnp.asarray(prompt_lens, jnp.int32)
            if prompt_lens.shape != (b,):
                raise ValueError(
                    f"prompt_lens shape {prompt_lens.shape} != ({b},) for a "
                    f"batch of {b} prompts")
            if not _slot_capable(cfg):
                raise ValueError(
                    f"prompt_lens needs per-request cache positions, which "
                    f"{cfg.name} (family={cfg.family}) does not support: "
                    f"recurrent/cross-attn state has no per-position layout")
            if window:
                raise ValueError(
                    f"prompt_lens is unsupported with a sliding-window ring "
                    f"cache (window={window}); serve {cfg.name} with "
                    f"window=0 or use serve.continuous (mask-windowed)")
            lens = jax.device_get(prompt_lens)
            if (lens < 1).any() or (lens > s).any():
                raise ValueError(
                    f"prompt_lens must lie in [1, {s}] (got {lens.tolist()})")
        logits, cache = self.api.prefill(self.params, prompt_batch, self.pctx,
                                         capacity=cap, window=self.window)
        if prompt_lens is None:
            last_logits = logits[:, -1]
        else:
            # ragged batch: row r's first token comes from its own last
            # PROMPT position, and its decode stream starts at len_r — the
            # per-row ``pos`` array routes decode_step into slot mode
            last_logits = jnp.take_along_axis(
                logits, (prompt_lens - 1)[:, None, None], axis=1)[:, 0]
            cache["pos"] = prompt_lens
        if key is None:
            key = jax.random.fold_in(self._base_key, self._n_calls)
        self._n_calls += 1

        stop = [int(t) for t in stop_tokens]
        if eos_id is not None and int(eos_id) not in stop:
            stop.append(int(eos_id))
        pad_id = int(eos_id) if eos_id is not None else (stop[0] if stop else 0)
        stop_arr = jnp.asarray(stop, jnp.int32) if stop else None
        finished = jnp.zeros((b,), bool)
        lengths = jnp.zeros((b,), jnp.int32)

        out_tokens: List[jnp.ndarray] = []
        out_lp: List[jnp.ndarray] = []
        for i in range(max_new_tokens):
            key, sub = jax.random.split(key)
            nxt = self._sample(last_logits, sub)
            nxt = jnp.where(finished, pad_id, nxt)
            lp = jax.nn.log_softmax(last_logits.astype(jnp.float32), -1)
            lp = jnp.take_along_axis(lp, nxt[:, None], 1)[:, 0]
            out_lp.append(jnp.where(finished, 0.0, lp))
            out_tokens.append(nxt)
            lengths = lengths + (~finished).astype(jnp.int32)
            if stop_arr is not None:
                finished = finished | jnp.isin(nxt, stop_arr)
                if bool(finished.all()):
                    break
            step = {"tokens": nxt[:, None]}
            logits_d, cache = self._decode(self.params, cache, step)
            last_logits = logits_d[:, -1]
        n_pad = max_new_tokens - len(out_tokens)
        if n_pad:
            out_tokens += [jnp.full((b,), pad_id, jnp.int32)] * n_pad
            out_lp += [jnp.zeros((b,), jnp.float32)] * n_pad
        return GenerationResult(
            tokens=jnp.stack(out_tokens, axis=1),
            logprobs=jnp.stack(out_lp, axis=1),
            prefill_len=s, lengths=lengths)

    def _sample(self, logits, key):
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits.astype(jnp.float32) / self.temperature, axis=-1
        ).astype(jnp.int32)
