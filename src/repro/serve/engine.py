"""Batched serving engine: prefill + greedy/temperature decode loop.

A deliberately small but real engine: static request batching, one jitted
prefill, one jitted decode step reused across tokens, KV/state cache threaded
functionally.  The decode_32k / long_500k dry-run shapes lower exactly the
``decode_step`` this engine calls per token.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.models.api import ModelApi


@dataclasses.dataclass
class GenerationResult:
    tokens: jnp.ndarray          # (B, max_new)
    logprobs: jnp.ndarray        # (B, max_new)
    prefill_len: int


class ServeEngine:
    def __init__(self, api: ModelApi, params, *, pctx=None, window=None,
                 temperature: float = 0.0):
        self.api = api
        self.params = params
        self.pctx = pctx
        self.window = window
        self.temperature = temperature
        self._decode = jax.jit(
            lambda p, cache, batch: api.decode_fn(p, cache, batch, pctx,
                                                  window=window))

    def generate(self, prompt_batch: dict, *, max_new_tokens: int,
                 capacity: Optional[int] = None,
                 key: Optional[jax.Array] = None) -> GenerationResult:
        """prompt_batch: dict(tokens (B, S) [, prefix/frames]).

        Greedy when temperature == 0, else temperature sampling.
        """
        tokens = prompt_batch["tokens"]
        b, s = tokens.shape
        cap = capacity or (s + max_new_tokens + 8)
        logits, cache = self.api.prefill(self.params, prompt_batch, self.pctx,
                                         capacity=cap, window=self.window)
        out_tokens: List[jnp.ndarray] = []
        out_lp: List[jnp.ndarray] = []
        last_logits = logits[:, -1]
        if key is None:
            key = jax.random.PRNGKey(0)
        for i in range(max_new_tokens):
            key, sub = jax.random.split(key)
            nxt = self._sample(last_logits, sub)
            lp = jax.nn.log_softmax(last_logits.astype(jnp.float32), -1)
            out_lp.append(jnp.take_along_axis(lp, nxt[:, None], 1)[:, 0])
            out_tokens.append(nxt)
            step = {"tokens": nxt[:, None]}
            logits_d, cache = self._decode(self.params, cache, step)
            last_logits = logits_d[:, 0]
        return GenerationResult(
            tokens=jnp.stack(out_tokens, axis=1),
            logprobs=jnp.stack(out_lp, axis=1),
            prefill_len=s)

    def _sample(self, logits, key):
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits.astype(jnp.float32) / self.temperature, axis=-1
        ).astype(jnp.int32)
