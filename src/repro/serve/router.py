"""Fault-tolerant multi-replica request router over ``ContinuousEngine``.

This is the serving-side execution of the planner's ``replicas`` axis:
``HybridPlanner.best_inference`` picks a (replicas x tp, slots) layout and
``ReplicaRouter.from_choice`` instantiates it — N independent continuous-
batching engine groups, each on its own tp-device mesh, behind one
admission front door with least-loaded dispatch.  Robustness is the point:
at the scale where multi-group layouts win, replica failure is the norm,
and a replica dying must not lose its in-flight requests.

Failover state machine
======================

Per replica::

    healthy --kill fault/process loss--------------> dead
    healthy --watchdog timeout (stall)-------------> degraded
    healthy --non-finite logprob (nanlogits)-------> degraded
    healthy --drain_replica()----------------------> draining --empty--> removed

- **healthy**: dispatchable, stepped every router tick.  Health is
  observed, not assumed: each engine step runs inside an armed
  ``train.fault.Watchdog`` (tick-progress heartbeat), and every logprob
  the replica emits is checked for NaN/Inf.
- **dead**: the engine is gone (simulated SIGKILL).  Its state is
  unreachable — recovery uses only the ROUTER-side streaming records
  (progress through the replica's last completed tick).
- **degraded**: the engine object still exists but is quarantined — a
  replica that hangs past the watchdog or emits non-finite logits cannot
  be trusted with further work.  Its requests are harvested exactly like
  a dead replica's (for nanlogit faults the generated suffix from the
  first non-finite logprob onward is discarded — those tokens came from
  poisoned math).
- **draining/removed**: elastic shrink, mirroring PR 7's elastic DP —
  no new dispatch, in-flight work finishes, then the replica is removed.
  ``add_replica()`` is the matching grow.

Per request::

    submitted --dispatch--> on replica r --finish--> result (exactly once)
        |                        |
        | projected wait >       | replica dead/degraded
        |   deadline             v
        +--> shed            retry wait (capped exponential backoff)
                                 |  deadline-aware: a retry that cannot
                                 |  start before the deadline times out
                                 v
                             re-dispatched with replay_tokens

Failover re-dispatch is **bit-identical** to an unfaulted run: every
replica engine shares the same base seed, sampling keys are (rid, n_gen)-
addressed (independent of batch/replica placement), and the new replica
re-prefills the prompt exactly as a fresh run would, then REPLAYS the
already-generated tokens through the same decode ticks that produced them
(see ``Request.replay_tokens``) — reconstructing the original computation
op for op instead of re-prefilling prompt+generated in one shot (which
would reorder attention reductions and drift in the last bits).

Fault injection reuses the ``train.fault`` schedule grammar, replica-keyed:
``kill@N:R`` (replica R dies before router tick N), ``stall@N:R:SECS``
(replica R hangs inside tick N; the watchdog flags it), ``nanlogits@N:R``
(replica R's tick N emits NaN logprobs).  Like training faults, a fault at
tick N fires when tick N is *about to run*, so schedules are reproducible.

Load shedding: admission is bounded twice — per-engine ``max_queue``
(hard bound on queued requests) and, for deadline-carrying requests, a
projected-wait check: ``backlog_tokens x EWMA(step seconds)`` on the
least-loaded replica; if that already overshoots the deadline the request
is shed at the door (``finished_reason="shed"``) instead of timing out
after consuming resources.  Every submitted rid lands in ``results``
exactly once — completed, shed, or timed out.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.serve.continuous import ContinuousEngine, Request, RequestResult
from repro.train.fault import Fault, Watchdog

REPLICA_FAULT_KINDS = ("kill", "stall", "nanlogits")


@dataclasses.dataclass
class _Replica:
    idx: int
    engine: Optional[ContinuousEngine]
    state: str = "healthy"   # healthy|degraded|dead|draining|removed
    stalled: bool = False    # set by the watchdog thread, read post-step

    @property
    def live(self) -> bool:
        return self.state in ("healthy", "draining")


@dataclasses.dataclass
class _Tracked:
    """Router-side streaming record for one in-flight rid: the original
    request plus progress mirrored after every completed replica tick —
    the only thing failover from a DEAD replica can recover from."""
    req: Request
    replica: Optional[int]           # None while waiting for a retry slot
    tokens: List[int] = dataclasses.field(default_factory=list)
    logprobs: List[float] = dataclasses.field(default_factory=list)
    failovers: int = 0
    ready_at: float = 0.0            # retry backoff gate (absolute)
    deadline: Optional[float] = None  # absolute; None = no deadline


def _valid_prefix(tokens: Sequence[int], logprobs: Sequence[float]):
    """Progress up to (excluding) the first non-finite logprob: everything
    from poisoned math onward is untrusted and must be regenerated."""
    for i, lp in enumerate(logprobs):
        if not math.isfinite(lp):
            return list(tokens[:i]), list(logprobs[:i])
    return list(tokens), list(logprobs)


class ReplicaRouter:
    """See module docstring.  ``faults`` takes replica-keyed ``Fault``s
    (``train.fault.parse_fault_schedule`` forms ``kill@N:R`` /
    ``stall@N:R:SECS`` / ``nanlogits@N:R``); training-form faults (no
    replica) are rejected.  ``clock``/``sleep_fn`` are injectable for
    deterministic tests; the watchdog and injected stalls use real time
    (the watchdog is a timer thread)."""

    def __init__(self, api, params, *, replicas: int, n_slots: int,
                 capacity: int, prefill_chunk: int = 0,
                 temperature: float = 0.0, seed: int = 0,
                 meshes: Optional[Sequence] = None,
                 model_axis: Optional[str] = None, batch_axes=(),
                 comm_chunks: int = 1, window=None,
                 context_axis: Optional[str] = None,
                 max_queue: Optional[int] = None,
                 faults: Sequence[Fault] = (),
                 watchdog_timeout_s: Optional[float] = None,
                 watchdog_warmup_ticks: int = 2,
                 retry_backoff_s: float = 0.05,
                 max_retry_backoff_s: float = 1.0,
                 est_step_s: float = 0.0,
                 clock: Callable[[], float] = time.monotonic,
                 sleep_fn: Callable[[float], None] = time.sleep,
                 log_fn: Callable[[str], None] = lambda m: None):
        if replicas < 1:
            raise ValueError(f"need at least one replica, got {replicas}")
        if meshes is not None and len(meshes) != replicas:
            raise ValueError(f"{len(meshes)} meshes for {replicas} replicas")
        for f in faults:
            if f.kind not in REPLICA_FAULT_KINDS or f.replica is None:
                raise ValueError(
                    f"router faults must be replica-keyed "
                    f"{REPLICA_FAULT_KINDS} (kind@tick:replica...), got "
                    f"{f.kind}@{f.step} with replica={f.replica}")
        self._api, self._params = api, params
        self._engine_kw = dict(
            n_slots=n_slots, capacity=capacity, prefill_chunk=prefill_chunk,
            temperature=temperature, seed=seed, model_axis=model_axis,
            batch_axes=batch_axes, comm_chunks=comm_chunks, window=window,
            context_axis=context_axis, max_queue=max_queue, clock=clock)
        self._meshes = list(meshes) if meshes is not None else None
        self.replicas: List[_Replica] = []
        for r in range(replicas):
            self.replicas.append(_Replica(r, self._make_engine(r)))
        self.faults = [dataclasses.replace(f) for f in faults]
        self.fault_log: List[tuple] = []     # (kind, tick, replica)
        self._clock, self._sleep, self._log = clock, sleep_fn, log_fn
        self._watchdog = (Watchdog(watchdog_timeout_s, self._on_stall)
                          if watchdog_timeout_s is not None else None)
        # the first steps JIT-compile the prefill/decode functions (seconds,
        # vs milliseconds once warm) — arming the heartbeat there would flag
        # compilation as a stall on every replica
        self._watchdog_warmup = watchdog_warmup_ticks
        self.retry_backoff_s = retry_backoff_s
        self.max_retry_backoff_s = max_retry_backoff_s
        self._est_step_s = est_step_s        # EWMA seconds per engine step
        self.ticks = 0
        self.tracked: Dict[int, _Tracked] = {}
        self.results: List[RequestResult] = []
        self.stats = {"completed": 0, "shed": 0, "timed_out": 0,
                      "failovers": 0}

    def _make_engine(self, idx: int) -> ContinuousEngine:
        mesh = self._meshes[idx] if self._meshes is not None else None
        return ContinuousEngine(self._api, self._params, mesh=mesh,
                                **self._engine_kw)

    @classmethod
    def from_choice(cls, api, params, choice, *, capacity: int, **kw):
        """Build the router an ``InferenceChoice`` plans: ``choice.replicas``
        engine groups of ``choice.tp`` devices each (disjoint device
        subsets, tensor-parallel inside the group when tp > 1) with
        ``choice.slots`` request lanes per group."""
        meshes = None
        model_axis, batch_axes = None, ()
        if choice.tp > 1:
            devs = jax.devices()
            need = choice.replicas * choice.tp
            if need > len(devs):
                raise ValueError(
                    f"choice needs {choice.replicas} x {choice.tp} = {need} "
                    f"devices, only {len(devs)} visible")
            meshes = [jax.sharding.Mesh(
                np.asarray(devs[r * choice.tp:(r + 1) * choice.tp]
                           ).reshape(1, choice.tp), ("data", "model"))
                for r in range(choice.replicas)]
            model_axis, batch_axes = "model", ("data",)
        return cls(api, params, replicas=choice.replicas,
                   n_slots=choice.slots, capacity=capacity, meshes=meshes,
                   model_axis=model_axis, batch_axes=batch_axes, **kw)

    # -- health ---------------------------------------------------------------

    def _on_stall(self, idx: int) -> None:
        self.replicas[idx].stalled = True

    @property
    def replica_states(self) -> List[str]:
        return [r.state for r in self.replicas]

    def _healthy(self) -> List[_Replica]:
        return [r for r in self.replicas if r.state == "healthy"]

    # -- admission ------------------------------------------------------------

    def submit(self, req: Request) -> Optional[RequestResult]:
        """Admit ``req``.  Returns ``None`` on acceptance or the shaped
        shed/timeout result on rejection; duplicate in-flight rids raise
        (same contract as ``ContinuousEngine.submit``)."""
        if req.rid in self.tracked:
            raise ValueError(
                f"request {req.rid}: a request with rid {req.rid} is "
                f"already in flight on the router")
        now = self._clock()
        tr = _Tracked(req=req, replica=None,
                      deadline=(now + req.deadline_s
                                if req.deadline_s is not None else None))
        self.tracked[req.rid] = tr
        try:
            return self._dispatch(tr, now)
        except Exception:
            del self.tracked[req.rid]        # invalid request never tracked
            raise

    def _backlog_tokens(self, rep: _Replica) -> int:
        eng = rep.engine
        return (sum(r.max_new_tokens for r in eng.queue)
                + sum(st.req.max_new_tokens - st.n_gen
                      for st in eng.active.values()))

    def _dispatch(self, tr: _Tracked, now: float):
        """Least-loaded dispatch with projected-wait shedding.  Returns the
        shaped result on shed/timeout, else None."""
        cands = self._healthy()
        if not cands:
            if any(r.state == "draining" for r in self.replicas):
                # shrink in progress: hold in the retry queue until the
                # drain finishes or the deadline expires
                tr.replica, tr.ready_at = None, now
                return None
            return self._finalize(tr, "shed")
        rep = min(cands, key=lambda r: (len(r.engine.queue)
                                        + len(r.engine.active), r.idx))
        if tr.deadline is not None:
            remaining = tr.deadline - now
            if remaining <= 0:
                return self._finalize(tr, "timed_out")
            projected = self._backlog_tokens(rep) * self._est_step_s
            if projected > remaining:
                self._log(f"[router] shed rid={tr.req.rid}: projected wait "
                          f"{projected:.3f}s > deadline {remaining:.3f}s")
                return self._finalize(tr, "shed")
        req = dataclasses.replace(
            tr.req, replay_tokens=tuple(tr.tokens),
            replay_logprobs=tuple(tr.logprobs),
            deadline_s=(tr.deadline - now
                        if tr.deadline is not None else None))
        res = rep.engine.submit(req)
        if res is not None:                  # engine max_queue shed
            rep.engine.results.pop()         # router owns the accounting
            return self._finalize(tr, "shed")
        tr.replica = rep.idx
        return None

    def _finalize(self, tr: _Tracked, reason: str,
                  res: Optional[RequestResult] = None) -> RequestResult:
        if res is None:
            res = RequestResult(rid=tr.req.rid,
                                prompt_len=len(tr.req.tokens),
                                tokens=list(tr.tokens),
                                logprobs=list(tr.logprobs),
                                finished_reason=reason)
        self.results.append(res)
        self.stats["completed" if reason in ("eos", "length")
                   else reason] += 1
        del self.tracked[tr.req.rid]
        return res

    # -- failover -------------------------------------------------------------

    def _failover(self, tr: _Tracked, now: float) -> None:
        """Replica loss: keep the trusted progress prefix, park the request
        behind a capped exponential backoff, deadline-aware."""
        tr.tokens, tr.logprobs = _valid_prefix(tr.tokens, tr.logprobs)
        tr.replica = None
        tr.failovers += 1
        self.stats["failovers"] += 1
        backoff = min(self.retry_backoff_s * (2 ** (tr.failovers - 1)),
                      self.max_retry_backoff_s)
        tr.ready_at = now + backoff
        if tr.deadline is not None and tr.ready_at >= tr.deadline:
            self._finalize(tr, "timed_out")  # retry could never finish
            return
        self._log(f"[router] failover rid={tr.req.rid} "
                  f"({len(tr.tokens)} tokens kept, retry in {backoff:.3f}s)")

    def _harvest(self, rep: _Replica, now: float) -> None:
        """Pull every request assigned to ``rep`` back into the retry
        queue.  Uses the ROUTER-side records — a dead replica's engine
        state is unreachable by definition."""
        for tr in [t for t in self.tracked.values()
                   if t.replica == rep.idx]:
            self._failover(tr, now)

    def drain_replica(self, idx: int) -> None:
        """Elastic shrink: stop dispatching to replica ``idx``; its
        in-flight work finishes, then it is removed."""
        rep = self.replicas[idx]
        if rep.state == "healthy":
            rep.state = "draining"

    def add_replica(self) -> int:
        """Elastic grow: append a fresh healthy replica (same engine
        geometry; same seed, so failover onto it stays bit-identical)."""
        if self._meshes is not None:
            raise ValueError("add_replica with explicit meshes: provide the "
                             "new replica's device group via meshes instead")
        idx = len(self.replicas)
        self.replicas.append(_Replica(idx, self._make_engine(idx)))
        return idx

    # -- one router tick ------------------------------------------------------

    def _pending_faults(self, kind: str, tick: int, idx: int) -> List[Fault]:
        return [f for f in self.faults if f.kind == kind and f.step == tick
                and f.replica == idx and f.times > 0]

    def step(self) -> bool:
        """One router tick: fire scheduled faults, re-dispatch ready
        retries, step every live replica under the watchdog, mirror
        progress, collect results, quarantine unhealthy replicas.
        Returns True while any request is in flight."""
        tick = self.ticks + 1
        now = self._clock()

        # (1) re-dispatch retries whose backoff has elapsed
        for tr in list(self.tracked.values()):
            if tr.replica is None:
                if tr.deadline is not None and now >= tr.deadline:
                    self._finalize(tr, "timed_out")
                elif now >= tr.ready_at:
                    self._dispatch(tr, now)

        for rep in self.replicas:
            if not rep.live:
                continue
            # (2) scheduled faults fire when tick N is about to run
            killed = False
            for f in self._pending_faults("kill", tick, rep.idx):
                f.times = 0
                killed = True
            if killed:
                self.fault_log.append(("kill", tick, rep.idx))
                self._log(f"[router] replica {rep.idx} killed before "
                          f"tick {tick}")
                rep.state, rep.engine = "dead", None
                self._harvest(rep, now)
                continue
            for f in self._pending_faults("nanlogits", tick, rep.idx):
                f.times = 0
                self.fault_log.append(("nanlogits", tick, rep.idx))
                rep.engine.poison_decode_ticks(1)
            stall_s = 0.0
            for f in self._pending_faults("stall", tick, rep.idx):
                f.times = 0
                self.fault_log.append(("stall", tick, rep.idx))
                stall_s += f.seconds

            # (3) one engine step under the armed watchdog heartbeat
            armed = (self._watchdog is not None
                     and self.ticks >= self._watchdog_warmup)
            if armed:
                self._watchdog.arm(rep.idx)
            if stall_s > 0.0:
                self._sleep(stall_s)         # hang INSIDE the armed window
            t0 = self._clock()
            rep.engine.step()
            dt = self._clock() - t0 + stall_s
            if armed:
                self._watchdog.disarm()
            self._est_step_s = (dt if self._est_step_s <= 0.0
                                else 0.8 * self._est_step_s + 0.2 * dt)

            # (4) mirror per-rid progress (streaming records: what failover
            # from a dead replica recovers) and scan logprobs for poison
            poisoned = False
            for st in rep.engine.active.values():
                tr = self.tracked.get(st.req.rid)
                if tr is not None:
                    tr.tokens = list(st.tokens)
                    tr.logprobs = list(st.logprobs)
                    if st.logprobs and not math.isfinite(st.logprobs[-1]):
                        poisoned = True

            # (5) collect finished results; poisoned ones are NOT delivered
            for res in rep.engine.results:
                tr = self.tracked.get(res.rid)
                if tr is None:
                    continue                 # already accounted (defensive)
                if any(not math.isfinite(lp) for lp in res.logprobs):
                    poisoned = True
                    tr.tokens, tr.logprobs = _valid_prefix(res.tokens,
                                                           res.logprobs)
                else:
                    self._finalize(tr, res.finished_reason, res)
            rep.engine.results.clear()

            if rep.stalled or poisoned:
                why = "stalled past watchdog" if rep.stalled else "NaN/Inf logits"
                self._log(f"[router] replica {rep.idx} degraded ({why})")
                rep.state = "degraded"
                self._harvest(rep, now)
            elif rep.state == "draining" and not (rep.engine.active
                                                  or rep.engine.queue):
                rep.state, rep.engine = "removed", None

        self.ticks = tick
        if self.tracked and not any(r.live for r in self.replicas):
            raise RuntimeError(
                f"{len(self.tracked)} request(s) in flight but no live "
                f"replica remains (states: {self.replica_states})")
        return bool(self.tracked)

    def run(self, requests: Sequence[Request]) -> List[RequestResult]:
        """Submit everything, step until every rid has a result (exactly
        one per submitted rid), return results ordered by rid."""
        for r in requests:
            self.submit(r)
        while self.step():
            pass
        return sorted(self.results, key=lambda r: r.rid)

    def close(self) -> None:
        if self._watchdog is not None:
            self._watchdog.close()
