from repro.serve.engine import GenerationResult, ServeEngine

__all__ = ["GenerationResult", "ServeEngine"]
