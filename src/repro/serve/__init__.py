from repro.serve.continuous import ContinuousEngine, Request, RequestResult
from repro.serve.engine import GenerationResult, ServeEngine
from repro.serve.router import ReplicaRouter

__all__ = ["ContinuousEngine", "GenerationResult", "ReplicaRouter",
           "Request", "RequestResult", "ServeEngine"]
