from repro.serve.continuous import ContinuousEngine, Request, RequestResult
from repro.serve.engine import GenerationResult, ServeEngine

__all__ = ["ContinuousEngine", "GenerationResult", "Request",
           "RequestResult", "ServeEngine"]
