r"""Continuous-batching serving engine over a slotted KV cache.

Slot/admission model
====================

The engine owns ONE slotted cache (``models.api.make_slot_cache``):
``n_slots`` independent request lanes, each a linear KV region of
``capacity`` positions with its own write position (``cache["pos"]`` is
(n_slots,)).  Requests flow through three states:

    queued --admit--> prefilling --last chunk--> decoding --eos/budget--> done
                       (slot held)                (slot held)            (slot freed)
         \________________________ deadline_s ________________________/
          an expired request exits from ANY state at the next step() —
          slot freed, partial tokens returned flagged "timed_out"

Per ``step()`` the engine (1) **admits** queued requests into free slots,
(2) runs ONE prefill chunk for the head-of-line prefilling request —
chunked prefill is what keeps a long prompt from stalling the running
batch: decode ticks interleave between its chunks, (3) runs ONE decode
tick over ALL slots with an active-row mask, (4) **evicts** finished
requests (EOS or token budget) and frees their slots for the next
admission.  Everything the device sees is fixed-shape — admission and
eviction only edit slot rows and the mask, so joining requests never
retrace the jitted tick and (pinned by test) never perturb the tokens of
requests already in flight.

The decode tick comes from ``train.steps.make_continuous_steps``: under a
dp x tp mesh it executes ``transformer.decode_slots_tp`` — the whole layer
stack inside one shard_map with every Megatron matmul on the chunked
collective-matmul ppermute rings of ``parallel.collectives`` (no monolithic
all-gather / all-reduce in the compiled decode HLO).  The prefill chunk
shards the same way (``prefill_chunk_tp``: chunk sequence dim in the
ring-row role), or — with ``context_axis`` — context-parallel on the
ppermute KV ring (``prefill_chunk_cp``, ``parallel.context``).

Sampling keys fold ``(request id, tokens generated)`` into the engine seed,
so a request's random stream is independent of which other requests share
its batch — this is what makes mid-flight joins bit-reproducible.

Which (replicas x tp, slots) to deploy is the latency-SLO-constrained
search ``core.planner.HybridPlanner.best_inference``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.models.api import (ModelApi, cache_evict_slot, make_slot_cache)
from repro.train.steps import make_continuous_steps


@dataclasses.dataclass
class Request:
    rid: int
    tokens: Sequence[int]            # prompt token ids
    max_new_tokens: int
    eos_id: Optional[int] = None
    # TTL in seconds from submit().  Once expired the request is evicted —
    # queued or mid-flight — its slot freed, and its result returned with
    # finished_reason="timed_out" and whatever tokens were generated.  One
    # stalled long request can therefore never starve admission forever.
    deadline_s: Optional[float] = None
    # Failover resume (``serve.router``): tokens this request already
    # generated on a replica that died mid-flight, plus their logprobs.  The
    # engine prefills the prompt exactly as a fresh run would, then REPLAYS
    # these tokens through the same decode ticks that produced them (forced
    # instead of sampled) — reconstructing the unfaulted computation op for
    # op, so the continuation's tokens/logprobs are bit-identical to a run
    # that never failed over.  (A one-shot re-prefill of prompt + generated
    # would reorder the attention reductions and drift in the last bits.)
    replay_tokens: Sequence[int] = ()
    replay_logprobs: Sequence[float] = ()


@dataclasses.dataclass
class RequestResult:
    rid: int
    prompt_len: int
    tokens: List[int]                # generated ids (stop token included)
    logprobs: List[float]
    finished_reason: str             # "eos" | "length" | "timed_out" | "shed"


@dataclasses.dataclass
class _Active:
    req: Request
    slot: int
    consumed: int = 0                # prompt tokens prefilled so far
    n_gen: int = 0
    tokens: List[int] = dataclasses.field(default_factory=list)
    logprobs: List[float] = dataclasses.field(default_factory=list)
    last_logits: Optional[jnp.ndarray] = None   # set once prefill completes

    @property
    def decoding(self) -> bool:
        return self.last_logits is not None


class ContinuousEngine:
    """See module docstring.  ``prefill_chunk=0`` prefills each prompt in
    one shot (still interleaved with decode ticks); > 0 caps the tokens per
    prefill step.  ``mesh``/``model_axis``/``batch_axes`` route the decode
    tick onto the collective-ring TP step when the arch and slot count
    divide (``transformer.decode_slots_tp_supported``) and the prefill
    chunk onto ``prefill_chunk_tp`` (same rings, the chunk's sequence dim
    in the ring-row role).  ``context_axis`` instead routes the prefill
    chunk onto the sequence-sharded KV ring (``prefill_chunk_cp``)."""

    def __init__(self, api: ModelApi, params, *, n_slots: int, capacity: int,
                 prefill_chunk: int = 0, temperature: float = 0.0,
                 seed: int = 0, mesh=None, model_axis: Optional[str] = None,
                 batch_axes=(), comm_chunks: int = 1, window=None,
                 context_axis: Optional[str] = None,
                 max_queue: Optional[int] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.api = api
        self.params = params
        self.n_slots = n_slots
        self.capacity = capacity
        self.prefill_chunk = prefill_chunk
        self.temperature = temperature
        self.max_queue = max_queue    # bound on queued (not-yet-admitted) reqs
        self._clock = clock           # injectable for deterministic TTL tests
        self._deadline: Dict[int, float] = {}    # rid -> absolute deadline
        self._base_key = jax.random.PRNGKey(seed)
        self.cache = make_slot_cache(api.cfg, n_slots, capacity)
        (self._decode_tick, self._prefill_chunk,
         self._prefill_grid) = make_continuous_steps(
            api, n_slots=n_slots, temperature=temperature, mesh=mesh,
            model_axis=model_axis, batch_axes=batch_axes,
            comm_chunks=comm_chunks, window=window,
            context_axis=context_axis)
        self.queue: List[Request] = []
        self.active: Dict[int, _Active] = {}       # slot -> state
        self.results: List[RequestResult] = []
        self.ticks = 0                # completed step() count (heartbeat)
        self._poison_ticks = 0        # fault hook: decode ticks to NaN out

    # -- request lifecycle ---------------------------------------------------

    def submit(self, req: Request) -> Optional[RequestResult]:
        """Enqueue ``req``.  Returns ``None`` on acceptance; when
        ``max_queue`` is set and the queue is full, the request is REJECTED
        with a shaped ``RequestResult(finished_reason="shed")`` (appended to
        ``results`` and returned) instead of growing the queue without
        bound.  A rid already in flight raises: deadlines and results are
        rid-keyed, so a duplicate would silently overwrite the first
        request's deadline and corrupt its accounting."""
        in_flight = ({r.rid for r in self.queue}
                     | {st.req.rid for st in self.active.values()})
        if req.rid in in_flight:
            raise ValueError(
                f"request {req.rid}: a request with rid {req.rid} is already "
                f"in flight (queued or holding a slot) — rids key deadlines "
                f"and results, so submit each rid at most once until its "
                f"result is returned")
        n = len(req.tokens)
        if n + req.max_new_tokens > self.capacity:
            raise ValueError(
                f"request {req.rid}: prompt ({n}) + max_new_tokens "
                f"({req.max_new_tokens}) = {n + req.max_new_tokens} exceeds "
                f"slot capacity {self.capacity}")
        if len(req.replay_tokens) != len(req.replay_logprobs):
            raise ValueError(
                f"request {req.rid}: {len(req.replay_tokens)} replay tokens "
                f"but {len(req.replay_logprobs)} replay logprobs — the "
                f"failover resume needs one logprob per replayed token")
        if len(req.replay_tokens) > req.max_new_tokens:
            raise ValueError(
                f"request {req.rid}: {len(req.replay_tokens)} replay tokens "
                f"exceed max_new_tokens ({req.max_new_tokens})")
        if self._prefill_grid > 1:
            # sharded prefill pads the final chunk up to the ring grid; the
            # padded rows must still land inside the slot's linear region
            t_f = (n if self.prefill_chunk <= 0
                   else (n % self.prefill_chunk or self.prefill_chunk))
            pad = -t_f % self._prefill_grid
            if n + pad > self.capacity:
                raise ValueError(
                    f"request {req.rid}: prompt ({n}) + sharded-prefill pad "
                    f"({pad}, grid {self._prefill_grid}) exceeds slot "
                    f"capacity {self.capacity} — grow capacity by the pad "
                    f"slack or align the prompt to the chunk grid")
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            res = RequestResult(rid=req.rid, prompt_len=n, tokens=[],
                                logprobs=[], finished_reason="shed")
            self.results.append(res)
            return res
        if req.deadline_s is not None:
            self._deadline[req.rid] = self._clock() + req.deadline_s
        self.queue.append(req)
        return None

    def take_queued(self) -> List[Request]:
        """Remove and return every not-yet-admitted request — the router's
        drain/failover hook (queued requests hold no slot state, so they can
        re-dispatch to another replica as-is)."""
        out, self.queue = self.queue, []
        for r in out:
            self._deadline.pop(r.rid, None)
        return out

    def poison_decode_ticks(self, n: int = 1) -> None:
        """Fault hook (``serve.router`` nanlogits injection): the next ``n``
        decode ticks return NaN logprobs (and token 0) for every live row,
        emulating a replica whose math went bad (ECC fault, bad reduction).
        Consumed only by ticks that actually decode."""
        self._poison_ticks += n

    def _expire(self):
        """Evict every request past its deadline — mid-flight requests free
        their slot (partial tokens returned), queued requests never admit."""
        now = self._clock()
        for st in list(self.active.values()):
            dl = self._deadline.get(st.req.rid)
            if dl is not None and now >= dl:
                self._finish(st, "timed_out")
        kept = []
        for req in self.queue:
            dl = self._deadline.get(req.rid)
            if dl is not None and now >= dl:
                self._deadline.pop(req.rid, None)
                self.results.append(RequestResult(
                    rid=req.rid, prompt_len=len(req.tokens), tokens=[],
                    logprobs=[], finished_reason="timed_out"))
            else:
                kept.append(req)
        self.queue = kept

    def _admit(self):
        free = [s for s in range(self.n_slots) if s not in self.active]
        while free and self.queue:
            slot = free.pop(0)
            req = self.queue.pop(0)
            self.cache = cache_evict_slot(self.cache, slot)
            self.active[slot] = _Active(req=req, slot=slot)

    def _finish(self, st: _Active, reason: str):
        self._deadline.pop(st.req.rid, None)
        self.results.append(RequestResult(
            rid=st.req.rid, prompt_len=len(st.req.tokens),
            tokens=st.tokens, logprobs=st.logprobs, finished_reason=reason))
        del self.active[st.slot]

    # -- one scheduler step --------------------------------------------------

    def _request_key(self, st: _Active):
        # (rid, n_gen)-addressed stream: independent of batch composition
        return jax.random.fold_in(
            jax.random.fold_in(self._base_key, st.req.rid), st.n_gen)

    def _sample_from(self, st: _Active):
        """Sample st's next token from its held last-position logits (host
        path used at the prefill->decode transition; decode-tick sampling
        happens inside the jitted tick with the same key schedule)."""
        lg = st.last_logits.astype(jnp.float32)
        if self.temperature <= 0.0:
            nxt = int(lg.argmax(-1))
        else:
            nxt = int(jax.random.categorical(
                self._request_key(st), lg / self.temperature))
        lp = float(jax.nn.log_softmax(lg, -1)[nxt])
        return nxt, lp

    def step(self) -> bool:
        """Expire / admit / one prefill chunk / one decode tick / evict.
        Returns True while any work remains."""
        self._expire()     # before admit: a freed slot admits THIS step
        self._admit()

        # (2) one prefill chunk for the head-of-line prefilling request
        pre = next((st for st in self.active.values() if not st.decoding),
                   None)
        if pre is not None:
            prompt = jnp.asarray(pre.req.tokens, jnp.int32)
            n = len(pre.req.tokens)
            chunk = (n - pre.consumed if self.prefill_chunk <= 0
                     else min(self.prefill_chunk, n - pre.consumed))
            toks = prompt[pre.consumed:pre.consumed + chunk][None]
            self.cache, last = self._prefill_chunk(
                self.params, self.cache, toks, pre.slot)
            pre.consumed += chunk
            if pre.consumed == n:
                pre.last_logits = last[0]        # prefill done -> decoding

        # (3) one decode tick over every decoding slot
        deco = [st for st in self.active.values() if st.decoding]
        if deco:
            tokens = jnp.zeros((self.n_slots,), jnp.int32)
            active = jnp.zeros((self.n_slots,), bool)
            keys = jnp.zeros((self.n_slots, 2), jnp.uint32)
            for st in deco:
                # the token a decode tick consumes is sampled from the
                # PREVIOUS position's logits: held host-side at the
                # prefill->decode seam, in-tick afterwards.  A failover
                # resume splices its recorded token instead of sampling.
                if not st.tokens:
                    if st.req.replay_tokens:
                        st.tokens.append(int(st.req.replay_tokens[0]))
                        st.logprobs.append(float(st.req.replay_logprobs[0]))
                    else:
                        nxt, lp = self._sample_from(st)
                        st.tokens.append(nxt)
                        st.logprobs.append(lp)
                    st.n_gen += 1
            live = [st for st in deco
                    if not self._hit_stop(st)
                    and st.n_gen < st.req.max_new_tokens]
            for st in live:
                tokens = tokens.at[st.slot].set(st.tokens[-1])
                active = active.at[st.slot].set(True)
                keys = keys.at[st.slot].set(
                    jnp.asarray(self._request_key(st), jnp.uint32))
            if live:
                self.cache, nxt, lp = self._decode_tick(
                    self.params, self.cache, tokens, active, keys)
                nxt, lp = jax.device_get((nxt, lp))
                poisoned = self._poison_ticks > 0
                if poisoned:
                    self._poison_ticks -= 1
                for st in live:
                    k = st.n_gen
                    if k < len(st.req.replay_tokens):
                        # replay: the tick ran (extending the cache exactly
                        # as the original decode did) but the output is the
                        # recorded token, not a fresh sample
                        st.tokens.append(int(st.req.replay_tokens[k]))
                        st.logprobs.append(float(st.req.replay_logprobs[k]))
                    elif poisoned:
                        st.tokens.append(0)
                        st.logprobs.append(float("nan"))
                    else:
                        st.tokens.append(int(nxt[st.slot]))
                        st.logprobs.append(float(lp[st.slot]))
                    st.n_gen += 1

        # (4) evict finished requests, freeing slots for the next admit
        for st in list(self.active.values()):
            if not st.decoding:
                continue
            if self._hit_stop(st):
                self._finish(st, "eos")
            elif st.n_gen >= st.req.max_new_tokens:
                st.tokens = st.tokens[:st.req.max_new_tokens]
                st.logprobs = st.logprobs[:st.req.max_new_tokens]
                self._finish(st, "length")
        self.ticks += 1            # progress heartbeat (router health checks)
        return bool(self.active or self.queue)

    def _hit_stop(self, st: _Active) -> bool:
        return (st.req.eos_id is not None and st.tokens
                and st.tokens[-1] == st.req.eos_id)

    def run(self, requests: Sequence[Request]) -> List[RequestResult]:
        """Submit everything, step until drained, return results by rid."""
        for r in requests:
            self.submit(r)
        while self.step():
            pass
        return sorted(self.results, key=lambda r: r.rid)
