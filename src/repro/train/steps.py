"""Step builders: sharded train_step / prefill_step / serve_step factories.

``make_train_step`` builds the jit-able function plus its in/out shardings for
a (ModelApi, ParallelPlan, mesh); the launcher and the multi-pod dry-run both
call it.  Gradient accumulation implements the paper's §4.2 delayed-gradient
emulation of larger global batches: A micro-batches are processed per device
before one gradient exchange/update.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.api import ModelApi
from repro.models.transformer import ParallelCtx
from repro.optim.optimizers import Optimizer, apply_updates, clip_by_global_norm
from repro.parallel.plan import ParallelPlan
from repro.parallel.sharding import ShardingRules


@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=["params", "opt_state", "step"],
                   meta_fields=[])
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: Any


def init_train_state(api: ModelApi, optimizer: Optimizer, key) -> TrainState:
    params = api.init(key)
    return TrainState(params=params, opt_state=optimizer.init(params),
                      step=jnp.zeros((), jnp.int32))


def eval_train_state(api: ModelApi, optimizer: Optimizer) -> TrainState:
    """Abstract TrainState (ShapeDtypeStruct leaves) — the ``like`` tree for
    ``checkpoint.restore_checkpoint`` without allocating a real init (works
    for the 1T-param configs on the CPU host)."""
    return jax.eval_shape(
        lambda k: init_train_state(api, optimizer, k), jax.random.PRNGKey(0))


def _make_pctx(mesh, plan: ParallelPlan, batch_shardable: bool,
               decode: bool = False) -> Optional[ParallelCtx]:
    if mesh is None or plan.model_axis is None:
        return None
    axes = tuple(plan.dp_axes) if batch_shardable else ()
    # 2D EP (§Perf iteration B): in decode, per-step activations are ~MBs
    # while the expert bank is ~TBs — replicate tokens across the DP axes and
    # slice the expert hidden dim over them instead of gathering weights.
    # Training keeps batch-sharded dispatch (tokens >> weights per step).
    ff_axes = tuple(plan.dp_axes) if (decode or not batch_shardable) else ()
    if plan.mp_kind == "context":
        # The model axis hosts the KV ring, not tensor-MP compute: params
        # stay replicated across it (ShardingRules), activations sequence-
        # shard inside transformer.cp_block_apply.
        return ParallelCtx(mesh=mesh, batch_axes=axes if axes else (None,),
                           model_axis=None, context_axis=plan.model_axis,
                           moe_ff_axes=ff_axes,
                           comm_runtime=plan.comm_runtime,
                           comm_chunks=plan.comm_chunks)
    return ParallelCtx(mesh=mesh, batch_axes=axes if axes else (None,),
                       model_axis=plan.model_axis, moe_ff_axes=ff_axes,
                       comm_runtime=plan.comm_runtime,
                       comm_chunks=plan.comm_chunks)


def make_train_step(api: ModelApi, optimizer: Optimizer, *, mesh=None,
                    plan: ParallelPlan = ParallelPlan(), clip_norm: float = 1.0,
                    pctx: Optional[ParallelCtx] = None,
                    bucket_bytes: Optional[float] = None):
    """Returns ``train_step(state, batch) -> (state, metrics)`` (pure fn).

    ``mp_kind="pipeline"`` plans route the forward/backward through the
    arch's pipeline runtime selected by ``plan.runtime``: **"scheduled"**
    (default) calls ``api.pipeline_value_and_grad_fn`` — the hand-scheduled
    executor of the full fwd+bwd WorkUnit table
    (``parallel.pipeline.pipeline_value_and_grad``), which realizes the
    schedule's activation residency (1f1b holds min(K, S) micro-batches);
    **"ad"** keeps ``jax.value_and_grad`` of ``api.pipeline_loss_fn`` ->
    ``pipeline_apply`` (GPipe-like memory, the differential-testing
    baseline).  ``plan.microbatches`` then counts in-flight pipeline
    micro-batches, not delayed-gradient accumulation steps, so the
    accumulation loop is off.
    """
    pipelined = (plan.is_pipeline and mesh is not None
                 and mesh.shape[plan.model_axis] > 1)
    micro = 1 if pipelined else plan.microbatches

    if pipelined:
        # dp x stages: the mesh's DP axes shard each micro-batch inside the
        # pipeline shard_map; the gradient psum over them is GSPMD's
        batch_axes = tuple(a for a in plan.dp_axes
                           if mesh.shape.get(a, 1) > 1)
        pipe_kw = dict(mesh=mesh, axis=plan.model_axis,
                       n_micro=max(plan.microbatches, 1),
                       schedule=plan.schedule,
                       virtual_stages=plan.virtual_stages,
                       batch_axes=batch_axes)
        runtime_fn = (api.pipeline_value_and_grad_fn
                      if plan.runtime == "scheduled"
                      else api.pipeline_loss_fn)
        if runtime_fn is None:
            raise ValueError(
                f"{api.cfg.name}: plan requests pipeline-MP "
                f"({plan.runtime} runtime) but the arch has no pipeline "
                f"runtime (models.api.supports_pipeline)")

        if plan.runtime == "scheduled":
            def grads_of(params, batch):
                (loss, metrics), grads = runtime_fn(params, batch, **pipe_kw)
                return loss, metrics, grads
        else:
            def grads_of(params, batch):
                (loss, metrics), grads = jax.value_and_grad(
                    lambda p, b: runtime_fn(p, b, **pipe_kw),
                    has_aux=True)(params, batch)
                return loss, metrics, grads
    else:
        def loss_fn(params, batch):
            return api.loss_fn(params, batch, pctx)

        def grads_of(params, batch):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return loss, metrics, grads

    def total_grads(params, batch):
        if micro > 1:
            # delayed gradient update (paper §4.2): split the per-step batch
            # into `micro` micro-batches, accumulate grads, update once
            def split(x):
                b = x.shape[0]
                return x.reshape(micro, b // micro, *x.shape[1:])

            mbatch = jax.tree.map(split, batch)

            def body(acc, mb):
                loss, metrics, grads = grads_of(params, mb)
                acc = jax.tree.map(jnp.add, acc, grads)
                return acc, loss

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, losses = jax.lax.scan(
                body, zeros, mbatch)
            grads = jax.tree.map(lambda g: g / micro, grads)
            loss = losses.mean()
            return loss, {"loss": loss}, grads
        return grads_of(params, batch)

    # Bucketed DP gradient sync (comm_runtime="overlapped", pure-DP plans):
    # run the whole fwd+bwd(+accumulation) per-shard inside a shard_map and
    # sync gradients bucket-by-bucket through the ZeRO-style reduce-scatter
    # + all-gather split instead of GSPMD's single fused all-reduce — per
    # bucket collectives are what the scheduler can overlap with the
    # backward compute still producing later buckets.  Tensor/pipeline-MP
    # and fsdp plans keep GSPMD's sync (their params are not replicated
    # over DP, so the replicated-params shard_map does not apply).
    dp_axes_live = tuple(a for a in plan.dp_axes
                         if mesh is not None and mesh.shape.get(a, 1) > 1)
    dp_degree = 1
    for a in dp_axes_live:
        dp_degree *= mesh.shape[a]
    bucketed_dp = (plan.comm_runtime == "overlapped" and not pipelined
                   and mesh is not None and not plan.fsdp_axes
                   and dp_degree > 1
                   and (plan.model_axis is None
                        or mesh.shape.get(plan.model_axis, 1) == 1))
    if bucketed_dp:
        from repro.parallel.collectives import (DEFAULT_BUCKET_BYTES,
                                                bucketed_grad_sync)

        gspmd_total_grads = total_grads
        dp_axis = dp_axes_live[-1]
        pod_axis = dp_axes_live[0] if len(dp_axes_live) > 1 else None
        bkt = DEFAULT_BUCKET_BYTES if bucket_bytes is None else bucket_bytes

        def total_grads(params, batch):
            # per-shard batch must split over DP and still divide into the
            # accumulation micro-batches; otherwise keep GSPMD's fused sync
            b = jax.tree.leaves(batch)[0].shape[0]
            if b % dp_degree or (micro > 1 and (b // dp_degree) % micro):
                return gspmd_total_grads(params, batch)

            def local(p, bt):
                loss, metrics, grads = gspmd_total_grads(p, bt)
                grads = bucketed_grad_sync(grads, dp_axis=dp_axis,
                                           dp_size=mesh.shape[dp_axis],
                                           pod_axis=pod_axis,
                                           bucket_bytes=bkt)
                grads = jax.tree.map(
                    lambda g: (g / dp_degree).astype(g.dtype), grads)
                loss = jax.lax.pmean(loss, dp_axes_live)
                metrics = {k: jax.lax.pmean(v, dp_axes_live)
                           for k, v in metrics.items()}
                return loss, metrics, grads

            from repro.parallel.jaxcompat import shard_map
            return shard_map(local, mesh=mesh,
                             in_specs=(P(), P(dp_axes_live)),
                             out_specs=(P(), P(), P()))(params, batch)

    def train_step(state: TrainState, batch):
        params = state.params
        loss, metrics, grads = total_grads(params, batch)
        if clip_norm:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
            metrics = dict(metrics, grad_norm=gnorm)
        updates, opt_state = optimizer.update(grads, state.opt_state, params,
                                              state.step)
        params = apply_updates(params, updates)
        new_state = TrainState(params=params, opt_state=opt_state,
                               step=state.step + 1)
        return new_state, metrics

    return train_step


def shardings_for(api: ModelApi, mesh, plan: ParallelPlan, optimizer: Optimizer,
                  input_specs):
    """(state_shardings, batch_shardings) for jit in_shardings/out_shardings.

    Derives everything from shape-level eval_shape — no allocation, so this
    works for the 1T-param configs on the CPU host.
    """
    rules = ShardingRules(api.cfg, mesh, plan)
    key = jax.random.PRNGKey(0)
    params_shape = jax.eval_shape(api.init, key)
    p_spec = rules.params_specs(params_shape)
    p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), p_spec,
                           is_leaf=lambda x: isinstance(x, P))
    opt_shape = jax.eval_shape(optimizer.init, params_shape)
    # path-based wrapper-key resolution lives with the rule engine so the
    # elastic-resume path can derive full-state shardings too
    o_spec = rules.opt_specs(params_shape, opt_shape)
    o_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), o_spec,
                           is_leaf=lambda x: isinstance(x, P))
    state_shardings = TrainState(params=p_shard, opt_state=o_shard,
                                 step=NamedSharding(mesh, P()))
    if "cache" in input_specs:
        cache_spec = rules.cache_specs(input_specs["cache"])
        rest = {k: v for k, v in input_specs.items() if k != "cache"}
        b_spec = rules.batch_specs(rest)
        b_spec["cache"] = cache_spec
    else:
        b_spec = rules.batch_specs(input_specs)
    b_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), b_spec,
                           is_leaf=lambda x: isinstance(x, P))
    return state_shardings, b_shard


def _lookup(tree, path):
    node = tree
    for p in path:
        key = getattr(p, "key", getattr(p, "idx", None))
        if isinstance(node, dict):
            node = node[key]
        else:
            node = node[int(key)]
    return node


def make_serve_steps(api: ModelApi, *, pctx=None, window=None):
    """(prefill_step, decode_step) pure fns for the serving engine/dry-run."""

    def prefill_step(params, batch, capacity):
        return api.prefill(params, batch, pctx, capacity=capacity, window=window)

    def decode_step(params, batch):
        cache = batch["cache"]
        rest = {k: v for k, v in batch.items() if k != "cache"}
        logits, new_cache = api.decode_fn(params, cache, rest, pctx, window=window)
        return logits, new_cache

    return prefill_step, decode_step


def make_continuous_steps(api: ModelApi, *, n_slots: int,
                          temperature: float = 0.0, mesh=None,
                          model_axis: Optional[str] = None, batch_axes=(),
                          comm_chunks: int = 1, window=None,
                          context_axis: Optional[str] = None):
    """Jitted ``(decode_tick, prefill_chunk, prefill_grid)`` triple for the
    continuous-batching engine (``serve.continuous``).

    ``decode_tick(params, cache, tokens, active, keys)`` runs ONE token step
    for every slot of a slotted cache — sampling happens inside the jit, and
    ``pos`` only advances for ``active`` slots (an inactive slot's write at
    its frozen position is overwritten at its next admission).  When a mesh
    with a >1 model axis is given and the arch/slot-count divides
    (``transformer.decode_slots_tp_supported``), the tick executes
    ``decode_slots_tp`` — the whole layer stack in one shard_map on the
    chunked collective-matmul rings.  ``prefill_chunk(params, cache, tokens,
    slot)`` extends one slot by a token chunk (slot-mode decode with t > 1,
    causal within the chunk) and returns the chunk's last-position logits.

    The prefill chunk is sharded too: under the tensor-MP mesh it routes
    through ``transformer.prefill_chunk_tp`` (same collective-matmul rings
    as the decode tick, the chunk's sequence dim in the ring-row role);
    with ``context_axis`` set it routes through ``prefill_chunk_cp`` — the
    chunk sequence-sharded over the ppermute KV ring of
    ``parallel.context``.  Routing is static per chunk length (jit
    re-traces per shape).  A chunk that does not divide the ring —
    typically a prompt's final chunk — is PADDED up to ``prefill_grid``
    (ring size x comm chunks for TP, ring size for CP) and runs the SAME
    sharded path with ``n_valid`` marking the real length; there is no
    single-device fallback once the arch supports the sharded step.  The
    returned ``prefill_grid`` (1 when unsharded) lets the engine validate
    that the pad rows fit the slot capacity.
    """
    from repro.models import transformer as tf_mod

    cfg = api.cfg
    use_tp = (mesh is not None and model_axis is not None
              and tf_mod.decode_slots_tp_supported(
                  cfg, mesh, model_axis, batch_axes, n_slots,
                  max(comm_chunks, 1)))
    # sharded-prefill routing is arch/mesh-static; only the chunk length
    # varies per call, and padding makes every length divide the grid
    cp_grid = tp_grid = 0
    if mesh is not None and context_axis is not None:
        csz = mesh.shape[context_axis]
        if tf_mod.prefill_chunk_cp_supported(cfg, mesh, context_axis, csz):
            cp_grid = csz
    if not cp_grid and mesh is not None and model_axis is not None:
        msz = mesh.shape[model_axis]
        g = msz * max(comm_chunks, 1)
        if tf_mod.prefill_chunk_tp_supported(cfg, mesh, model_axis, g,
                                             max(comm_chunks, 1)):
            tp_grid = g

    def _sample(last, keys):
        last = last.astype(jnp.float32)
        if temperature <= 0.0:
            nxt = last.argmax(-1).astype(jnp.int32)
        else:
            nxt = jax.vmap(
                lambda lg, k: jax.random.categorical(k, lg / temperature)
            )(last, keys).astype(jnp.int32)
        lp = jnp.take_along_axis(jax.nn.log_softmax(last, axis=-1),
                                 nxt[:, None], axis=-1)[:, 0]
        return nxt, lp

    def decode_tick(params, cache, tokens, active, keys):
        if use_tp:
            logits, new_cache = tf_mod.decode_slots_tp(
                cfg, params, cache, {"tokens": tokens[:, None]}, mesh=mesh,
                model_axis=model_axis, batch_axes=batch_axes,
                comm_chunks=comm_chunks, window_override=window)
        else:
            logits, new_cache = api.decode_fn(params, cache,
                                              {"tokens": tokens[:, None]},
                                              None, window)
        nxt, lp = _sample(logits[:, -1], keys)
        new_cache["pos"] = jnp.where(active, cache["pos"] + 1, cache["pos"])
        return new_cache, nxt, lp

    def prefill_chunk(params, cache, tokens, slot):
        from repro.models.api import cache_extract_slot, cache_insert_slot
        sl = cache_extract_slot(cache, slot)
        t = tokens.shape[1]          # static per trace: routing is per-shape
        if cp_grid or tp_grid:
            grid = cp_grid or tp_grid
            t_pad = -(-t // grid) * grid
            toks = (tokens if t_pad == t else
                    jnp.pad(tokens, ((0, 0), (0, t_pad - t))))
            nv = t if t_pad != t else None
            if cp_grid:
                logits, sl = tf_mod.prefill_chunk_cp(
                    cfg, params, sl, {"tokens": toks}, mesh=mesh,
                    context_axis=context_axis, window_override=window,
                    n_valid=nv)
            else:
                logits, sl = tf_mod.prefill_chunk_tp(
                    cfg, params, sl, {"tokens": toks}, mesh=mesh,
                    model_axis=model_axis, comm_chunks=comm_chunks,
                    window_override=window, n_valid=nv)
        else:
            logits, sl = api.decode_fn(params, sl, {"tokens": tokens}, None,
                                       window)
        return cache_insert_slot(cache, sl, slot), logits[:, -1]

    return (jax.jit(decode_tick, donate_argnums=(1,)),
            jax.jit(prefill_chunk, donate_argnums=(1,)),
            cp_grid or tp_grid or 1)
