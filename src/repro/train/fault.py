"""Deterministic fault injection + the process-level training supervisor.

Every failure mode the fault-tolerant runtime must survive is reproducible
from a seeded schedule, so recovery is a *test*, not an anecdote:

    faults = parse_fault_schedule("fail@5x2, corrupt@10:bitflip, kill@15")
    inj = FaultInjector(faults)
    summary = run_supervised(inj.wrap_step(train_step), pipeline, cfg,
                             init_fn=..., on_checkpoint=inj.after_save)

Fault kinds (``kind@step`` grammar, comma-separated):

- ``fail@N`` / ``fail@NxT`` — the wrapped train step raises
  ``InjectedFault`` when step N is about to run, T consecutive times
  (default 1).  Exercises the loop's bounded retry and, when T exceeds
  ``max_retries``, the supervisor's checkpoint-restore restart.
- ``kill@N`` — simulated preemption: ``os._exit(KILL_EXIT_CODE)`` before
  step N completes — no atexit, no cleanup, like SIGKILL.  Recovery is a
  fresh process resuming from the newest valid checkpoint
  (``launch.train --resume``).
- ``corrupt@N`` / ``corrupt@N:truncate`` — damages the checkpoint written
  *at* step N right after its save completes (``after_save`` hook):
  ``bitflip`` flips one byte inside the leaf data, ``truncate`` cuts the
  file in half.  Exercises CRC detection and ``restore_latest_valid``'s
  fallback to the previous checkpoint.
- ``stall@N:SECS`` — the step stalls SECS seconds before running (a hung
  data pipeline / collective).  Exercises the loop's watchdog flagging.

A fault at step N fires when step N is *about to run* (the last completed
step is N-1), so "kill@N, resume" and an uninterrupted run execute the
exact same sequence of step transitions.

**Replica-keyed serving faults** (``serve.router``): the same grammar
addresses a replica group instead of the training loop — ``N`` is the
router tick about to run, ``R`` the replica index:

- ``kill@N:R`` — replica R dies before router tick N (its engine is gone;
  in-flight requests fail over to a healthy replica).
- ``stall@N:R:SECS`` — replica R hangs SECS seconds inside tick N; the
  router's per-replica ``Watchdog`` flags it.  Disambiguated from the
  training form by arg count (two ``:`` args = replica form).
- ``nanlogits@N:R`` — replica R's tick N produces NaN logprobs (a silent
  numerical fault, e.g. a flipped bit in an accumulator); the router's
  logit health check marks the replica degraded.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
import zlib
from typing import Callable, List, Optional, Sequence

import jax

from repro.checkpoint import restore_latest_valid

KILL_EXIT_CODE = 17     # distinctive exit for injected preemption

FAULT_KINDS = ("fail", "kill", "corrupt", "stall", "nanlogits")
CORRUPT_MODES = ("bitflip", "truncate")


class InjectedFault(RuntimeError):
    """Raised by the injector's wrapped step for ``fail`` faults."""


@dataclasses.dataclass
class Fault:
    kind: str                 # "fail" | "kill" | "corrupt" | "stall" | "nanlogits"
    step: int                 # the step (or router tick) the fault is keyed to
    times: int = 1            # fail: consecutive raises before clearing
    mode: str = "bitflip"     # corrupt: "bitflip" | "truncate"
    seconds: float = 0.25     # stall: sleep duration
    replica: Optional[int] = None   # serving faults: target replica index

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")
        if self.kind == "corrupt" and self.mode not in CORRUPT_MODES:
            raise ValueError(f"unknown corrupt mode {self.mode!r}; "
                             f"expected one of {CORRUPT_MODES}")
        if self.step < 1:
            raise ValueError(f"fault step must be >= 1, got {self.step}")
        if self.kind == "nanlogits" and self.replica is None:
            raise ValueError("nanlogits faults are replica-keyed: "
                             "use nanlogits@N:R")
        if self.replica is not None and self.replica < 0:
            raise ValueError(f"fault replica must be >= 0, got {self.replica}")


def parse_fault_schedule(spec: str) -> List[Fault]:
    """Parse ``"fail@5x2, kill@7, corrupt@10:truncate, stall@3:0.4"``.

    Replica-keyed serving forms (``serve.router``): ``kill@N:R``,
    ``stall@N:R:SECS``, ``nanlogits@N:R``.  ``stall`` is disambiguated by
    arg count — one ``:`` arg is the training form (seconds), two is the
    replica form (replica, seconds)."""
    faults = []
    for item in spec.split(","):
        item = item.strip()
        if not item:
            continue
        if "@" not in item:
            raise ValueError(f"fault {item!r}: expected kind@step[...]")
        kind, _, rest = item.partition("@")
        kind = kind.strip()
        parts = rest.split(":")
        rest, args = parts[0], parts[1:]
        times = 1
        if "x" in rest:
            rest, _, t = rest.partition("x")
            times = int(t)
        step = int(rest)
        if kind == "corrupt":
            if len(args) > 1:
                raise ValueError(f"fault {item!r}: corrupt takes at most "
                                 f"one ':' arg (the mode)")
            faults.append(Fault(kind, step, mode=args[0] if args else "bitflip"))
        elif kind == "stall":
            if len(args) == 2:          # replica form: stall@N:R:SECS
                faults.append(Fault(kind, step, replica=int(args[0]),
                                    seconds=float(args[1])))
            elif len(args) <= 1:
                faults.append(Fault(kind, step,
                                    seconds=float(args[0]) if args else 0.25))
            else:
                raise ValueError(f"fault {item!r}: stall takes SECS or "
                                 f"R:SECS after the step")
        elif kind == "kill":
            if len(args) > 1:
                raise ValueError(f"fault {item!r}: kill takes at most "
                                 f"one ':' arg (the replica)")
            faults.append(Fault(kind, step,
                                replica=int(args[0]) if args else None))
        elif kind == "nanlogits":
            if len(args) != 1:
                raise ValueError(f"fault {item!r}: nanlogits is "
                                 f"replica-keyed — use nanlogits@N:R")
            faults.append(Fault(kind, step, replica=int(args[0])))
        else:
            if args:
                raise ValueError(f"fault {item!r}: {kind} takes no ':' arg")
            faults.append(Fault(kind, step, times=times))
    return faults


def corrupt_checkpoint(fname: str, mode: str = "bitflip",
                       seed: int = 0) -> None:
    """Deterministically damage a checkpoint file in place."""
    size = os.path.getsize(fname)
    if mode == "truncate":
        with open(fname, "r+b") as f:
            f.truncate(max(size // 2, 1))
        return
    if mode != "bitflip":
        raise ValueError(f"unknown corrupt mode {mode!r}")
    # land in the back half of the file — the leaf-data region, past the
    # msgpack header — at a seed-deterministic offset
    off = size // 2 + (zlib.crc32(str(seed).encode()) % max(size // 4, 1))
    with open(fname, "r+b") as f:
        f.seek(off)
        b = f.read(1)
        f.seek(off)
        f.write(bytes([(b[0] if b else 0) ^ 0xFF]))


class FaultInjector:
    """Wraps the training step / checkpoint hook to fire a ``Fault``
    schedule at reproducible points.  ``fired`` records ``(kind, step)`` in
    firing order for assertions."""

    def __init__(self, faults: Sequence[Fault],
                 log_fn: Callable[[str], None] = print):
        self.faults = [dataclasses.replace(f) for f in faults]
        self.log_fn = log_fn
        self.fired: List[tuple] = []

    def _pending(self, kind: str, step: int) -> List[Fault]:
        return [f for f in self.faults
                if f.kind == kind and f.step == step and f.times > 0]

    def wrap_step(self, train_step: Callable) -> Callable:
        """Supervisor wrapper: checks the schedule against the step ABOUT to
        run (``int(state.step) + 1``) before delegating.  Raising/killing
        happens before the real step, so the held state stays retryable."""

        def wrapped(state, batch):
            step = int(jax.device_get(state.step)) + 1
            for f in self._pending("stall", step):
                f.times = 0
                self.fired.append(("stall", step))
                self.log_fn(f"[fault] stalling {f.seconds:.2f}s before "
                            f"step {step}")
                time.sleep(f.seconds)
            for f in self._pending("kill", step):
                self.fired.append(("kill", step))
                self.log_fn(f"[fault] killing process before step {step} "
                            f"(exit {KILL_EXIT_CODE})")
                os._exit(KILL_EXIT_CODE)
            for f in self._pending("fail", step):
                f.times -= 1
                self.fired.append(("fail", step))
                raise InjectedFault(
                    f"injected step failure at step {step} "
                    f"({f.times} repeats left)")
            return train_step(state, batch)

        return wrapped

    def after_save(self, fname: str, step: int) -> None:
        """``on_checkpoint`` hook: corrupts the checkpoint written at the
        scheduled step, right after its write completed."""
        for f in self._pending("corrupt", step):
            f.times = 0
            self.fired.append(("corrupt", step))
            self.log_fn(f"[fault] corrupting ({f.mode}) checkpoint "
                        f"{os.path.basename(fname)}")
            corrupt_checkpoint(fname, f.mode)


class Watchdog:
    """Arms a timer around each step; fires ``on_timeout(tag)`` if the step
    does not ``disarm()`` within ``timeout_s``.  Detection only — it never
    kills the step (a slow step completes; the flag marks it)."""

    def __init__(self, timeout_s: float, on_timeout: Callable):
        self.timeout_s = timeout_s
        self.on_timeout = on_timeout
        self._timer: Optional[threading.Timer] = None

    def arm(self, tag) -> None:
        self.disarm()
        self._timer = threading.Timer(self.timeout_s, self.on_timeout, (tag,))
        self._timer.daemon = True
        self._timer.start()

    def disarm(self) -> None:
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None

    def close(self) -> None:
        self.disarm()


def run_supervised(train_step: Callable, pipeline, cfg, *,
                   init_fn: Callable[[], object],
                   like=None, shardings=None, max_restarts: int = 2,
                   restart_backoff_s: float = 0.05,
                   log_fn: Callable[[str], None] = print,
                   on_checkpoint: Optional[Callable] = None,
                   replan_fn: Optional[Callable] = None,
                   sleep_fn: Callable[[float], None] = time.sleep) -> dict:
    """Process-level supervisor: run ``train_loop`` to completion, restarting
    from the newest *valid* checkpoint (``restore_latest_valid`` skips
    corrupt files) when an attempt dies, up to ``max_restarts`` times with
    exponential backoff (``sleep_fn`` injects the backoff sleep so tests can
    pin the wait sequence without wall-clock time).
    ``init_fn() -> state`` builds the step-0 state when
    no checkpoint exists; ``like`` (default: ``jax.eval_shape(init_fn)``)
    types the restore; ``shardings`` re-shards restored leaves onto the
    current mesh — the elastic grow/shrink path.

    ``replan_fn(device_count) -> (train_step, shardings) | None`` closes the
    elastic loop: it is called before every attempt with the CURRENT
    ``jax.device_count()`` so a resume after DP grow/shrink re-runs the
    planner for the device count it actually has — instead of requiring the
    caller to replay the old ``--parallel`` spec — and returns the re-planned
    step + shardings (or None to keep the current pair).
    ``launch.train --parallel auto --resume`` builds exactly this (a bare
    ``--resume`` keeps the run's default plan so same-topology resume stays
    bit-reproducible).

    Returns the completing attempt's summary plus ``restarts``."""
    from repro.train.loop import train_loop

    if like is None:
        like = jax.eval_shape(init_fn)
    attempt = 0
    while True:
        if replan_fn is not None:
            replanned = replan_fn(jax.device_count())
            if replanned is not None:
                train_step, shardings = replanned
        state, source = None, "fresh init"
        if cfg.ckpt_dir:
            restored, fname = restore_latest_valid(cfg.ckpt_dir, like,
                                                   shardings)
            if restored is not None:
                state, source = restored, os.path.basename(fname)
        if state is None:
            state = init_fn()
        if attempt:
            log_fn(f"[supervisor] restart {attempt}/{max_restarts} "
                   f"from {source}")
        try:
            summary = train_loop(train_step, state, pipeline, cfg,
                                 log_fn=log_fn, on_checkpoint=on_checkpoint)
            summary["restarts"] = attempt
            return summary
        except Exception as e:
            attempt += 1
            if attempt > max_restarts:
                raise
            delay = restart_backoff_s * (2 ** (attempt - 1))
            log_fn(f"[supervisor] attempt died ({type(e).__name__}: {e}); "
                   f"restarting in {delay:.2f}s")
            sleep_fn(delay)
