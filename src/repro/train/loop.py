"""Supervised training loop: data pipeline, sharded train step, metrics,
hardened checkpointing, and failure handling.

The loop is the *inner* layer of the fault-tolerance stack (the outer layer —
process-level restarts and checkpoint-fallback — is ``train.fault.
run_supervised``):

- **Resume** is implicit: the loop starts at ``int(state.step)`` and
  fast-forwards the data pipeline to exactly that point
  (``DataPipeline.locate`` + ``epoch(e, skip=n)``), so a restored run
  consumes precisely the batches an uninterrupted run would have — no sample
  replayed or dropped, which is what makes kill-and-resume bit-equal to a
  straight run on the same topology.
- **Bounded retry**: a step that raises is retried up to
  ``max_retries`` times with exponential backoff, re-running the same batch
  from the held pre-step state.  If the failure invalidated the state's
  donated buffers the error propagates instead (only a checkpoint restore
  can recover — the supervisor's job).
- **Watchdog**: ``watchdog_timeout_s > 0`` arms a timer around every step;
  a step exceeding it is flagged (logged + counted in the summary) — the
  detection half of hang handling, without killing a slow-but-alive step.
- **Checkpointing**: every ``ckpt_every`` steps (``keep_last`` retention,
  optional ``background_save`` moving serialization off the critical path)
  plus a guaranteed synchronous final checkpoint at loop exit, so the exit
  state is always resumable.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

import jax

from repro.checkpoint import save_checkpoint, wait_for_saves
from repro.data.pipeline import DataPipeline


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    log_every: int = 20
    ckpt_every: int = 0
    ckpt_dir: str = ""
    target_loss: Optional[float] = None
    keep_last: int = 0              # checkpoint retention (0 = keep all)
    background_save: bool = False   # serialize + write off the step path
    final_ckpt: bool = True         # guaranteed checkpoint at loop exit
    max_retries: int = 0            # bounded per-step retries
    retry_backoff_s: float = 0.05   # exponential backoff base
    watchdog_timeout_s: float = 0.0  # > 0: flag steps exceeding this


def _tree_live(state) -> bool:
    """False once any leaf's buffer was donated/deleted (a failed jitted call
    may have consumed the input — retrying in place would be UB)."""
    for leaf in jax.tree.leaves(state):
        if getattr(leaf, "is_deleted", None) and leaf.is_deleted():
            return False
    return True


def train_loop(train_step: Callable, state, pipeline: DataPipeline,
               cfg: LoopConfig, *, log_fn: Callable[[str], None] = print,
               on_checkpoint: Optional[Callable[[str, int], None]] = None
               ) -> Dict:
    """Runs from ``int(state.step)`` up to cfg.total_steps (or until
    target_loss).  Returns a summary dict; see module docstring for the
    failure-handling semantics.  ``on_checkpoint(fname, step)`` fires after
    each completed checkpoint write (the fault-injection hook)."""
    try:
        start = int(jax.device_get(state.step))
    except (TypeError, ValueError):
        start = 0
    step = start
    epoch, skip = pipeline.locate(start)
    if start:
        log_fn(f"[loop] resuming at step {start} "
               f"(epoch {epoch}, skipping {skip} batches)")

    losses, history = [], []
    retries = hangs = n_ckpts = 0
    last_saved = None
    converged = False
    t0 = time.time()
    t_last, s_last = t0, step

    watchdog = None
    if cfg.watchdog_timeout_s > 0:
        from repro.train.fault import Watchdog

        def flag(tag):
            nonlocal hangs
            hangs += 1
            log_fn(f"[watchdog] step {tag} exceeded "
                   f"{cfg.watchdog_timeout_s:.2f}s — flagging hang")

        watchdog = Watchdog(cfg.watchdog_timeout_s, on_timeout=flag)

    def save(at_step: int, background: bool):
        nonlocal last_saved, n_ckpts
        fname = save_checkpoint(cfg.ckpt_dir, state, at_step,
                                keep_last=cfg.keep_last,
                                background=background)
        last_saved = at_step
        n_ckpts += 1
        if on_checkpoint is not None:
            if background:
                wait_for_saves()    # the hook inspects the finished file
            on_checkpoint(fname, at_step)

    def run_step(batch):
        nonlocal retries
        attempt = 0
        while True:
            try:
                if watchdog:
                    watchdog.arm(step + 1)
                new_state, metrics = train_step(state, batch)
                loss = float(metrics["loss"])   # sync inside watchdog window
                return new_state, metrics, loss
            except Exception as e:
                if watchdog:
                    watchdog.disarm()    # before the backoff sleep
                if attempt >= cfg.max_retries or not _tree_live(state):
                    raise
                attempt += 1
                retries += 1
                delay = cfg.retry_backoff_s * (2 ** (attempt - 1))
                log_fn(f"[loop] step {step + 1} failed "
                       f"({type(e).__name__}: {e}); retry "
                       f"{attempt}/{cfg.max_retries} in {delay:.2f}s")
                time.sleep(delay)
            finally:
                if watchdog:
                    watchdog.disarm()

    try:
        while step < cfg.total_steps:
            n_in_epoch = 0
            for batch in pipeline.epoch(epoch, skip=skip):
                n_in_epoch += 1
                state, metrics, loss = run_step(batch)
                step += 1
                losses.append(loss)
                history.append(loss)
                if step % cfg.log_every == 0:
                    now = time.time()
                    rate = (step - s_last) / max(now - t_last, 1e-9)
                    t_last, s_last = now, step
                    log_fn(f"step {step:6d} epoch {epoch:3d} "
                           f"loss {sum(losses)/len(losses):7.4f} "
                           f"{rate:6.2f} steps/s")
                    losses = []
                if cfg.ckpt_every and cfg.ckpt_dir \
                        and step % cfg.ckpt_every == 0:
                    save(step, cfg.background_save)
                if step >= cfg.total_steps:
                    break
                if cfg.target_loss is not None and loss <= cfg.target_loss:
                    converged = True
                    break
            if converged or step >= cfg.total_steps:
                break
            if n_in_epoch == 0 and skip == 0:
                raise RuntimeError(
                    f"data pipeline yielded an empty epoch ({epoch}) with "
                    f"{cfg.total_steps - step} steps still to run — the "
                    f"dataset/batch combination produces no batches")
            epoch += 1
            skip = 0
        # guaranteed final checkpoint: the exit state is always resumable
        if cfg.ckpt_dir and cfg.final_ckpt and step > start \
                and last_saved != step:
            save(step, background=False)
    finally:
        if cfg.background_save:
            wait_for_saves()
        if watchdog:
            watchdog.close()

    return {"state": state, "steps": step, "epochs": epoch,
            "final_loss": history[-1] if history else float("nan"),
            "history": history, "wall_s": time.time() - t0,
            "converged": converged, "start_step": start,
            "retries": retries, "hangs": hangs, "checkpoints": n_ckpts,
            "last_checkpoint_step": last_saved}
