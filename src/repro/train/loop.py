"""Training loop: wiring of data pipeline, sharded train step, metrics, and
checkpointing."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import save_checkpoint
from repro.data.pipeline import DataPipeline


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    log_every: int = 20
    ckpt_every: int = 0
    ckpt_dir: str = ""
    target_loss: Optional[float] = None


def train_loop(train_step: Callable, state, pipeline: DataPipeline,
               cfg: LoopConfig, *, log_fn: Callable[[str], None] = print
               ) -> Dict:
    """Runs up to cfg.total_steps (or until target_loss).  Returns summary."""
    step = 0
    epoch = 0
    losses = []
    t0 = time.time()
    t_last, s_last = t0, 0
    history = []
    while step < cfg.total_steps:
        for batch in pipeline.epoch(epoch):
            state, metrics = train_step(state, batch)
            step += 1
            loss = float(metrics["loss"])
            losses.append(loss)
            history.append(loss)
            if step % cfg.log_every == 0:
                now = time.time()
                rate = (step - s_last) / (now - t_last)
                t_last, s_last = now, step
                log_fn(f"step {step:6d} epoch {epoch:3d} "
                       f"loss {sum(losses)/len(losses):7.4f} "
                       f"{rate:6.2f} steps/s")
                losses = []
            if cfg.ckpt_every and step % cfg.ckpt_every == 0 and cfg.ckpt_dir:
                save_checkpoint(cfg.ckpt_dir, state, step)
            if step >= cfg.total_steps:
                break
            if cfg.target_loss is not None and loss <= cfg.target_loss:
                return {"state": state, "steps": step, "epochs": epoch,
                        "final_loss": loss, "history": history,
                        "wall_s": time.time() - t0, "converged": True}
        epoch += 1
    return {"state": state, "steps": step, "epochs": epoch,
            "final_loss": history[-1] if history else float("nan"),
            "history": history, "wall_s": time.time() - t0,
            "converged": False}
