from repro.train.fault import (Fault, FaultInjector, InjectedFault, Watchdog,
                               corrupt_checkpoint, parse_fault_schedule,
                               run_supervised)
from repro.train.loop import LoopConfig, train_loop
from repro.train.steps import (TrainState, eval_train_state, init_train_state,
                               make_serve_steps, make_train_step,
                               shardings_for)

__all__ = ["LoopConfig", "train_loop", "TrainState", "init_train_state",
           "eval_train_state", "make_serve_steps", "make_train_step",
           "shardings_for", "Fault", "FaultInjector", "InjectedFault",
           "Watchdog", "corrupt_checkpoint", "parse_fault_schedule",
           "run_supervised"]
