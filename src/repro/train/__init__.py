from repro.train.loop import LoopConfig, train_loop
from repro.train.steps import (TrainState, init_train_state, make_serve_steps,
                               make_train_step, shardings_for)

__all__ = ["LoopConfig", "train_loop", "TrainState", "init_train_state",
           "make_serve_steps", "make_train_step", "shardings_for"]
