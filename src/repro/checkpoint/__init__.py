"""Sharded checkpointing: save/restore TrainState pytrees via msgpack.

Arrays are gathered to host (fully addressable in this single-process
deployment; under multi-controller each host would write its shard files —
the directory layout already namespaces by shard), serialized with msgpack +
raw little-endian buffers, and restored with ``device_put`` against the
current mesh's NamedShardings so a checkpoint can be re-sharded across plan
changes — this is the elastic-resume path: a 16-way-DP run's checkpoint
restores bit-equal onto an 8- or 32-device mesh because the file holds the
*global* (unsharded) value of every leaf.

On-disk format (version 2)
==========================

One msgpack map per checkpoint file ``ckpt_{step:08d}.msgpack``::

    {"version": 2,
     "step":    <int>,
     "treedef": <str(jax.tree.structure(state))>,
     "manifest": [{"dtype": "float32", "shape": [4, 8], "crc32": <uint32>},
                  ...],                      # one entry per leaf, tree order
     "leaves":  [<raw little-endian bytes>, ...]}

The manifest is the integrity contract: ``restore_checkpoint`` re-computes
each leaf's CRC32 over the raw buffer and checks dtype/shape both against
the manifest and against the ``like`` tree it restores into.  Failures are
*typed*:

- ``CheckpointCorruptionError`` — the file is damaged (truncated msgpack,
  CRC mismatch, buffer/shape byte-count disagreement).  Recoverable by
  falling back to an older checkpoint.
- ``ValueError`` — the file is intact but does not match ``like`` (leaf
  count, per-leaf dtype/shape): the caller is restoring into the wrong
  architecture/optimizer.  Never silently skipped.

``restore_latest_valid`` implements the fallback: it walks the directory's
checkpoints newest-first and returns the first one that verifies and
restores, warning about (and skipping) corrupt files — a seeded
fault-injection schedule that bit-flips the newest checkpoint
(``train.fault``) lands on the previous one instead of crashing the run.

Writes are crash-safe: payload goes to a uniquely-named ``*.tmp-<pid>``
sibling, is fsync'd, then atomically ``os.replace``'d into place, so a kill
mid-save never yields a half-written ``ckpt_*.msgpack``; leftover ``.tmp``
files from a previous incarnation are swept on the next save.
``keep_last=N`` retains only the N newest checkpoints.  ``background=True``
moves msgpack packing + CRC + disk I/O off the step critical path onto a
writer thread (the device->host gather stays synchronous so donation of the
live state is safe); ``wait_for_saves()`` joins all pending writes and
re-raises their first error.

Version-1 files (leaves as ``{"dtype","shape","data"}`` dicts, no CRC) are
still restored — structural validation applies, integrity checking is best
effort (byte counts only).
"""
from __future__ import annotations

import os
import threading
import warnings
import zlib
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

FORMAT_VERSION = 2


class CheckpointError(Exception):
    """Base class for checkpoint failures."""


class CheckpointCorruptionError(CheckpointError):
    """The file on disk is damaged (truncation, bit rot, partial write)."""


# -- background writer -------------------------------------------------------

_IO_LOCK = threading.Lock()          # serializes finalize (rename + cleanup)
_PENDING: List[threading.Thread] = []
_PENDING_TMP: set = set()            # tmp paths owned by in-flight writers
_BG_ERRORS: List[BaseException] = []


def wait_for_saves() -> None:
    """Join every pending background save; re-raise the first failure."""
    while True:
        with _IO_LOCK:
            if not _PENDING:
                break
            t = _PENDING[0]
        t.join()
        with _IO_LOCK:
            if t in _PENDING:
                _PENDING.remove(t)
    with _IO_LOCK:
        if _BG_ERRORS:
            err = _BG_ERRORS[0]
            _BG_ERRORS.clear()
            raise CheckpointError("background checkpoint save failed") from err


def _sweep_orphan_tmps(path: str) -> None:
    """Remove ``.tmp`` droppings from crashed runs (not in-flight writes)."""
    try:
        names = os.listdir(path)
    except OSError:
        return
    for n in names:
        if ".tmp" not in n:
            continue
        full = os.path.join(path, n)
        with _IO_LOCK:
            if full in _PENDING_TMP:
                continue
        try:
            os.remove(full)
        except OSError:
            pass


def _apply_retention(path: str, keep_last: int) -> None:
    if keep_last <= 0:
        return
    for old in list_checkpoints(path)[:-keep_last]:
        try:
            os.remove(old)
        except OSError:
            pass


# -- save --------------------------------------------------------------------

def save_checkpoint(path: str, state: Any, step: Optional[int] = None, *,
                    keep_last: int = 0, background: bool = False) -> str:
    """Serialize a pytree (TrainState or params) to ``path``/ckpt_{step}.msgpack.

    ``keep_last=N`` (N > 0) deletes all but the N newest checkpoints after a
    successful write.  ``background=True`` gathers leaves to host
    synchronously (so the caller may immediately donate ``state``) and runs
    packing + CRC + write on a worker thread; call ``wait_for_saves()`` to
    flush.  Returns the final checkpoint filename either way.
    """
    os.makedirs(path, exist_ok=True)
    flat, treedef = jax.tree.flatten(state)
    # device -> host now: the caller's next train step donates these buffers
    host = [np.asarray(jax.device_get(x)) for x in flat]
    step = int(step if step is not None else _state_step(state))
    fname = os.path.join(path, f"ckpt_{step:08d}.msgpack")
    tmp = f"{fname}.tmp-{os.getpid()}"

    def job():
        manifest, leaves = [], []
        for arr in host:
            buf = np.ascontiguousarray(arr).tobytes()
            manifest.append({"dtype": str(arr.dtype),
                             "shape": list(arr.shape),
                             "crc32": zlib.crc32(buf) & 0xFFFFFFFF})
            leaves.append(buf)
        payload = {"version": FORMAT_VERSION, "step": step,
                   "treedef": str(treedef), "manifest": manifest,
                   "leaves": leaves}
        with open(tmp, "wb") as f:
            f.write(msgpack.packb(payload, use_bin_type=True))
            f.flush()
            os.fsync(f.fileno())
        with _IO_LOCK:
            os.replace(tmp, fname)
            _PENDING_TMP.discard(tmp)
        _apply_retention(path, keep_last)
        _sweep_orphan_tmps(path)

    if not background:
        try:
            job()
        finally:
            with _IO_LOCK:
                _PENDING_TMP.discard(tmp)
        return fname

    with _IO_LOCK:
        _PENDING_TMP.add(tmp)

    def guarded():
        try:
            job()
        except BaseException as e:                 # surfaced by wait_for_saves
            with _IO_LOCK:
                _BG_ERRORS.append(e)
                _PENDING_TMP.discard(tmp)

    t = threading.Thread(target=guarded, name=f"ckpt-save-{step}", daemon=True)
    with _IO_LOCK:
        _PENDING.append(t)
    t.start()
    return fname


def _state_step(state) -> int:
    step = getattr(state, "step", None)
    try:
        return int(step) if step is not None else 0
    except Exception:
        return 0


# -- directory queries -------------------------------------------------------

def list_checkpoints(path: str) -> List[str]:
    """All checkpoint files under ``path``, oldest first."""
    if not os.path.isdir(path):
        return []
    return [os.path.join(path, f) for f in sorted(os.listdir(path))
            if f.startswith("ckpt_") and f.endswith(".msgpack")]


def latest_checkpoint(path: str) -> Optional[str]:
    cands = list_checkpoints(path)
    return cands[-1] if cands else None


def checkpoint_step(fname: str) -> int:
    """Step number encoded in a checkpoint filename."""
    base = os.path.basename(fname)
    try:
        return int(base[len("ckpt_"):].split(".")[0])
    except ValueError:
        raise ValueError(f"not a checkpoint filename: {fname!r}") from None


# -- load / verify -----------------------------------------------------------

def _load_payload(fname: str) -> dict:
    try:
        with open(fname, "rb") as f:
            payload = msgpack.unpackb(f.read(), raw=False,
                                      strict_map_key=False)
    except OSError:
        raise
    except Exception as e:                 # truncation, garbage, bad msgpack
        raise CheckpointCorruptionError(
            f"{fname}: unreadable msgpack payload ({type(e).__name__}: {e})"
        ) from e
    if not isinstance(payload, dict) or "leaves" not in payload:
        raise CheckpointCorruptionError(
            f"{fname}: payload is not a checkpoint map")
    return payload


def _normalize(payload: dict, fname: str) -> Tuple[List[dict], List[bytes]]:
    """-> (manifest, raw buffers) for both v1 and v2 payloads."""
    leaves = payload["leaves"]
    if payload.get("version", 1) >= 2:
        manifest = payload.get("manifest")
        if not isinstance(manifest, list) or len(manifest) != len(leaves):
            raise CheckpointCorruptionError(
                f"{fname}: manifest/leaves length mismatch "
                f"({'missing' if manifest is None else len(manifest)} vs "
                f"{len(leaves)})")
        return manifest, leaves
    # v1: leaves are {"dtype","shape","data"} dicts with no CRC
    manifest = [{"dtype": d["dtype"], "shape": d["shape"], "crc32": None}
                for d in leaves]
    return manifest, [d["data"] for d in leaves]


def _decode_leaf(entry: dict, buf: bytes, fname: str, what: str) -> np.ndarray:
    dtype = np.dtype(entry["dtype"])
    shape = tuple(entry["shape"])
    want = int(np.prod(shape, dtype=np.int64)) * dtype.itemsize
    if len(buf) != want:
        raise CheckpointCorruptionError(
            f"{fname}: {what}: buffer holds {len(buf)} bytes, manifest "
            f"{dtype}{list(shape)} needs {want}")
    crc = entry.get("crc32")
    if crc is not None and (zlib.crc32(buf) & 0xFFFFFFFF) != crc:
        raise CheckpointCorruptionError(
            f"{fname}: {what}: CRC32 mismatch (stored {crc:#010x}, "
            f"computed {zlib.crc32(buf) & 0xFFFFFFFF:#010x}) — the leaf's "
            f"bytes were corrupted on disk")
    return np.frombuffer(buf, dtype=dtype).reshape(shape)


def verify_checkpoint(fname: str) -> dict:
    """Integrity-check every leaf (no ``like`` needed).  Returns
    ``{"step", "n_leaves", "bytes", "version"}``; raises
    ``CheckpointCorruptionError`` on damage."""
    payload = _load_payload(fname)
    manifest, bufs = _normalize(payload, fname)
    total = 0
    for i, (entry, buf) in enumerate(zip(manifest, bufs)):
        _decode_leaf(entry, buf, fname, f"leaf {i}")
        total += len(buf)
    return {"step": payload.get("step", checkpoint_step(fname)),
            "n_leaves": len(bufs), "bytes": total,
            "version": payload.get("version", 1)}


def _leaf_paths(like) -> List[str]:
    flat, _ = jax.tree_util.tree_flatten_with_path(like)
    def fmt(path):
        return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path) or "<root>"
    return [fmt(p) for p, _ in flat]


def restore_checkpoint(fname: str, like: Any, shardings: Any = None) -> Any:
    """Restore into the structure of ``like``; optionally re-shard with the
    provided NamedSharding pytree (the elastic grow/shrink path — leaves are
    global values, ``device_put`` lays them out for whatever mesh is current).

    Validates per-leaf integrity (CRC32) and structure: leaf count and every
    leaf's dtype/shape against ``like``.  See the module docstring for the
    error taxonomy."""
    payload = _load_payload(fname)
    manifest, bufs = _normalize(payload, fname)
    flat_like, treedef = jax.tree.flatten(like)
    if len(bufs) != len(flat_like):
        raise ValueError(
            f"{fname}: checkpoint has {len(bufs)} leaves but the restore "
            f"target has {len(flat_like)} — the checkpoint was written for a "
            f"different model/optimizer structure")
    paths = _leaf_paths(like)
    out_leaves = []
    for i, (entry, buf, want) in enumerate(zip(manifest, bufs, flat_like)):
        arr = _decode_leaf(entry, buf, fname, f"leaf {i} ({paths[i]})")
        want_shape = tuple(getattr(want, "shape", np.shape(want)))
        want_dtype = np.dtype(getattr(want, "dtype", np.asarray(want).dtype))
        if arr.shape != want_shape or arr.dtype != want_dtype:
            raise ValueError(
                f"{fname}: leaf {i} ({paths[i]}): checkpoint holds "
                f"{arr.dtype}{list(arr.shape)} but the restore target "
                f"expects {want_dtype}{list(want_shape)}")
        out_leaves.append(arr)
    if shardings is not None:
        flat_sh, _ = jax.tree.flatten(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))
        if len(flat_sh) != len(out_leaves):
            raise ValueError(
                f"{fname}: shardings tree has {len(flat_sh)} leaves, "
                f"expected {len(out_leaves)}")
        out = [jax.device_put(l, s) for l, s in zip(out_leaves, flat_sh)]
    else:
        out = [jnp.asarray(l) for l in out_leaves]
    return jax.tree.unflatten(treedef, out)


def restore_latest_valid(path: str, like: Any, shardings: Any = None
                         ) -> Tuple[Optional[Any], Optional[str]]:
    """Restore the newest checkpoint under ``path`` that passes integrity +
    structure validation, falling back over corrupt/mismatched files
    newest-first (each skip warns).  Returns ``(state, fname)``, or
    ``(None, None)`` when the directory holds no checkpoints at all.

    When checkpoints DO exist but every one fails validation, raises
    ``CheckpointCorruptionError`` instead: silently returning ``(None,
    None)`` would make the supervisor fresh-init and loop — retraining from
    step 0 while reporting a "restart" — when the run actually needs
    operator attention (all its state is gone)."""
    candidates = list(list_checkpoints(path))
    for fname in reversed(candidates):
        try:
            return restore_checkpoint(fname, like, shardings), fname
        except (CheckpointError, ValueError, OSError) as e:
            warnings.warn(f"[checkpoint] skipping {os.path.basename(fname)}: "
                          f"{e}", stacklevel=2)
    if candidates:
        raise CheckpointCorruptionError(
            f"all {len(candidates)} checkpoint(s) under {path} failed "
            f"validation — refusing to silently fresh-init over an "
            f"existing run")
    return None, None
