"""Sharded checkpointing: save/restore TrainState pytrees via msgpack.

Arrays are gathered to host (fully addressable in this single-process
deployment; under multi-controller each host would write its shard files —
the directory layout already namespaces by shard), serialized with msgpack +
raw little-endian buffers, and restored with ``device_put`` against the
current mesh's NamedShardings so a checkpoint can be re-sharded across plan
changes (e.g. resume a 16x16 run on 2x16x16).
"""
from __future__ import annotations

import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _pack_leaf(x) -> dict:
    arr = np.asarray(x)
    return {"dtype": str(arr.dtype), "shape": list(arr.shape),
            "data": arr.tobytes()}


def _unpack_leaf(d) -> np.ndarray:
    return np.frombuffer(d["data"], dtype=d["dtype"]).reshape(d["shape"])


def save_checkpoint(path: str, state: Any, step: Optional[int] = None) -> str:
    """Serialize a pytree (TrainState or params) to ``path``/ckpt_{step}.msgpack."""
    os.makedirs(path, exist_ok=True)
    flat, treedef = jax.tree.flatten(state)
    payload = {
        "treedef": str(treedef),
        "leaves": [_pack_leaf(x) for x in flat],
    }
    step = int(step if step is not None else _state_step(state))
    fname = os.path.join(path, f"ckpt_{step:08d}.msgpack")
    tmp = fname + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, fname)
    return fname


def _state_step(state) -> int:
    step = getattr(state, "step", None)
    try:
        return int(step) if step is not None else 0
    except Exception:
        return 0


def latest_checkpoint(path: str) -> Optional[str]:
    if not os.path.isdir(path):
        return None
    cands = sorted(f for f in os.listdir(path)
                   if f.startswith("ckpt_") and f.endswith(".msgpack"))
    return os.path.join(path, cands[-1]) if cands else None


def restore_checkpoint(fname: str, like: Any, shardings: Any = None) -> Any:
    """Restore into the structure of ``like``; optionally re-shard with the
    provided NamedSharding pytree."""
    with open(fname, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    flat_like, treedef = jax.tree.flatten(like)
    leaves = [_unpack_leaf(d) for d in payload["leaves"]]
    assert len(leaves) == len(flat_like), \
        f"checkpoint has {len(leaves)} leaves, expected {len(flat_like)}"
    if shardings is not None:
        flat_sh, _ = jax.tree.flatten(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))
        out = [jax.device_put(l, s) for l, s in zip(leaves, flat_sh)]
    else:
        out = [jnp.asarray(l) for l in leaves]
    return jax.tree.unflatten(treedef, out)
