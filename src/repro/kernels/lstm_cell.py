"""Fused LSTM cell Pallas TPU kernel (the paper's BigLSTM hot-spot — its
CuDNN "fused RNN kernel" analogue, DESIGN.md §3).

One kernel computes gates = x@Wx + h@Wh + b and the elementwise cell update,
so the (B, 4H) gates never round-trip to HBM.  Weights are laid out
(d_in, 4, H) so a column block covers all four gates of the same hidden
units.  Grid (B/bb, H/bh) with full-d contraction per tile (d_in <= ~8k fits
VMEM at bh=128: x tile (bb, d) + 2 weight tiles (d, 4, bh)).

Oracle: ``ref.lstm_cell_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ops import tpu_compiler_params


def _lstm_kernel(x_ref, h_ref, c_ref, wx_ref, wh_ref, b_ref, hn_ref, cn_ref):
    x = x_ref[...].astype(jnp.float32)            # (bb, d_in)
    h = h_ref[...].astype(jnp.float32)            # (bb, d_h_in)
    c = c_ref[...].astype(jnp.float32)            # (bb, bh)
    bb = x.shape[0]
    bh = c.shape[1]
    wx = wx_ref[...].astype(jnp.float32)          # (d_in, 4, bh)
    wh = wh_ref[...].astype(jnp.float32)          # (d_h_in, 4, bh)
    b = b_ref[...].astype(jnp.float32)            # (4, bh)
    gx = jax.lax.dot(x, wx.reshape(wx.shape[0], 4 * bh),
                     preferred_element_type=jnp.float32)
    gh = jax.lax.dot(h, wh.reshape(wh.shape[0], 4 * bh),
                     preferred_element_type=jnp.float32)
    gates = (gx + gh).reshape(bb, 4, bh) + b[None]
    i, f, g, o = gates[:, 0], gates[:, 1], gates[:, 2], gates[:, 3]
    c_new = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    hn_ref[...] = h_new.astype(hn_ref.dtype)
    cn_ref[...] = c_new.astype(cn_ref.dtype)


def lstm_cell(x, h, c, wx, wh, b, *, block_b: int = 128, block_h: int = 128,
              interpret: bool = False):
    """x: (B, d_in); h: (B, d_h_in); c: (B, H); wx: (d_in, 4, H);
    wh: (d_h_in, 4, H); b: (4, H).  Returns (h_new (B, H), c_new (B, H))."""
    bsz, d_in = x.shape
    hh = c.shape[1]
    bb = min(block_b, bsz)
    bh = min(block_h, hh)
    pb = (bb - bsz % bb) % bb
    ph = (bh - hh % bh) % bh
    if pb:
        x = jnp.pad(x, ((0, pb), (0, 0)))
        h = jnp.pad(h, ((0, pb), (0, 0)))
    if pb or ph:
        c = jnp.pad(c, ((0, pb), (0, ph)))
    if ph:
        wx = jnp.pad(wx, ((0, 0), (0, 0), (0, ph)))
        wh = jnp.pad(wh, ((0, 0), (0, 0), (0, ph)))
        b = jnp.pad(b, ((0, 0), (0, ph)))
    nb, nh = (bsz + pb) // bb, (hh + ph) // bh
    out_shape = [jax.ShapeDtypeStruct((bsz + pb, hh + ph), h.dtype),
                 jax.ShapeDtypeStruct((bsz + pb, hh + ph), c.dtype)]
    hn, cn = pl.pallas_call(
        _lstm_kernel,
        grid=(nb, nh),
        in_specs=[
            pl.BlockSpec((bb, d_in), lambda bi, hi: (bi, 0)),
            pl.BlockSpec((bb, h.shape[1]), lambda bi, hi: (bi, 0)),
            pl.BlockSpec((bb, bh), lambda bi, hi: (bi, hi)),
            pl.BlockSpec((d_in, 4, bh), lambda bi, hi: (0, 0, hi)),
            pl.BlockSpec((h.shape[1], 4, bh), lambda bi, hi: (0, 0, hi)),
            pl.BlockSpec((4, bh), lambda bi, hi: (0, hi)),
        ],
        out_specs=[pl.BlockSpec((bb, bh), lambda bi, hi: (bi, hi))] * 2,
        out_shape=out_shape,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(x, h, c, wx, wh, b)
    return hn[:bsz, :hh], cn[:bsz, :hh]
