"""jit'd dispatch wrappers for the Pallas kernels.

``use_pallas()`` decides per-platform: real kernels on TPU, interpret-mode
(Python-evaluated, bit-validating) on CPU when forced, jnp reference paths
otherwise.  Model code calls these wrappers so the kernel/reference choice is
a deployment flag, not a code change.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp


def tpu_compiler_params(**kwargs):
    """Compat shim: the Pallas-TPU params class is ``TPUCompilerParams`` on
    older jax releases and ``CompilerParams`` on newer ones.  Kernels call
    this instead of naming either class so both jax versions work.

    NOTE: defined before the kernel-module imports below on purpose — the
    kernel modules import it from here at module scope, which only resolves
    during a circular import if the name already exists.
    """
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "TPUCompilerParams", None) or pltpu.CompilerParams
    return cls(**kwargs)


from repro.kernels import flash_attention as _fa
from repro.kernels import lstm_cell as _lstm
from repro.kernels import moe_gmm as _gmm
from repro.kernels import ref as _ref
from repro.kernels import rwkv_scan as _wkv

_FORCE = os.environ.get("REPRO_KERNELS", "")  # "pallas" | "ref" | ""


def use_pallas() -> bool:
    if _FORCE == "pallas":
        return True
    if _FORCE == "ref":
        return False
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def attention(q, k, v, *, causal: bool = True, window: int = 0):
    """Flash attention (kv heads must be pre-repeated to q heads)."""
    if use_pallas():
        return _fa.flash_attention(q, k, v, causal=causal, window=window,
                                   interpret=_interpret())
    return _ref.attention_ref(q, k, v, causal=causal, window=window)


@functools.partial(jax.jit, static_argnames=("chunk",))
def wkv6(r, k, v, w, u, *, chunk: int = 128):
    if use_pallas():
        return _wkv.wkv6(r, k, v, w, u, chunk=chunk, interpret=_interpret())
    out, _ = _ref.wkv6_ref(r, k, v, w, u)
    return out


@jax.jit
def gmm(x, w):
    if use_pallas():
        return _gmm.gmm(x, w, interpret=_interpret())
    return _ref.gmm_ref(x, w)


@jax.jit
def lstm_cell(x, h, c, wx, wh, b):
    if use_pallas():
        return _lstm.lstm_cell(x, h, c, wx, wh, b, interpret=_interpret())
    return _ref.lstm_cell_ref(x, h, c, wx, wh, b)
