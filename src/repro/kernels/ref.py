"""Pure-jnp oracles for every Pallas kernel (the `ref.py` of kernels/).

These are *definitions of correctness*: small, obviously-right implementations
that the kernels' shape/dtype sweep tests assert_allclose against.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B,Tq,H,hd); k,v: (B,Tk,H,hd) — dense softmax attention."""
    b, tq, h, hd = q.shape
    tk = k.shape[1]
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    qpos = jnp.arange(tq)[:, None]
    kpos = jnp.arange(tk)[None, :]
    mask = jnp.ones((tq, tk), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def wkv6_ref(r, k, v, w, u):
    """Sequential RWKV6 recurrence (f32).  r,k,v,w: (B,T,H,hd); u: (H,hd)."""
    from repro.models.rwkv import wkv_scan
    return wkv_scan(r, k, v, w, u)


def gmm_ref(x, w, group_sizes=None):
    """Grouped matmul oracle: x (G, C, d) @ w (G, d, f) -> (G, C, f)."""
    return jnp.einsum("gcd,gdf->gcf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)


def lstm_cell_ref(x, h, c, wx, wh, b):
    """x: (B, d_in); h: (B, H); c: (B, H); wx: (d_in, 4, H); wh: (H_in, 4, H).

    Gate order (i, f, g, o); forget bias +1 (matches models/lstm.py).
    Returns (h', c')."""
    gates = jnp.einsum("bd,dgh->bgh", x.astype(jnp.float32), wx.astype(jnp.float32)) \
        + jnp.einsum("bd,dgh->bgh", h.astype(jnp.float32), wh.astype(jnp.float32)) \
        + b.astype(jnp.float32)
    i, f, g, o = gates[:, 0], gates[:, 1], gates[:, 2], gates[:, 3]
    c_new = jax.nn.sigmoid(f + 1.0) * c.astype(jnp.float32) \
        + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    return h_new.astype(h.dtype), c_new.astype(c.dtype)
