"""Grouped (per-expert) matmul Pallas TPU kernel for the MoE capacity buffer.

Computes out[g] = x[g] @ w[g] for G experts: grid (G, C/bc, F/bf, d/bd) with
the contraction axis innermost, accumulating in an f32 VMEM scratch tile.
This is the compute hot-spot of the sorted-capacity MoE dispatch
(``repro.models.moe._expert_compute``'s einsum); blocks are MXU-aligned
(128x128 output tiles).

Oracle: ``ref.gmm_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ops import tpu_compiler_params


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_d: int):
    di = pl.program_id(3)

    @pl.when(di == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]                                  # (bc, bd)
    w = w_ref[0]                                  # (bd, bf)
    acc_ref[...] += jax.lax.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(di == n_d - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def gmm(x, w, *, block_c: int = 128, block_f: int = 128, block_d: int = 512,
        interpret: bool = False):
    """x: (G, C, d); w: (G, d, F) -> (G, C, F)."""
    g, c, d = x.shape
    f = w.shape[2]
    bc = min(block_c, c)
    bf = min(block_f, f)
    bd = min(block_d, d)
    pc = (bc - c % bc) % bc
    pf = (bf - f % bf) % bf
    pd = (bd - d % bd) % bd
    if pc or pd:
        x = jnp.pad(x, ((0, 0), (0, pc), (0, pd)))
    if pd or pf:
        w = jnp.pad(w, ((0, 0), (0, pd), (0, pf)))
    n_c, n_f, n_d = (c + pc) // bc, (f + pf) // bf, (d + pd) // bd
    kernel = functools.partial(_gmm_kernel, n_d=n_d)
    out = pl.pallas_call(
        kernel,
        grid=(g, n_c, n_f, n_d),
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda gi, ci, fi, di: (gi, ci, di)),
            pl.BlockSpec((1, bd, bf), lambda gi, ci, fi, di: (gi, di, fi)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda gi, ci, fi, di: (gi, ci, fi)),
        out_shape=jax.ShapeDtypeStruct((g, c + pc, f + pf), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(x, w)
    return out[:, :c, :f]
