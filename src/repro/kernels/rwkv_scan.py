"""RWKV6 WKV recurrence Pallas TPU kernel.

Grid: (batch*heads, n_chunks) with the chunk axis sequential; the per-head
state S (hd x hd, f32) persists in VMEM scratch across chunk iterations, so
the HBM traffic is exactly r/k/v/w in + out out — the recurrence never spills.
Within a chunk the cross-token term is a (chunk x chunk) masked matmul on the
MXU, identical math to ``repro.models.rwkv.wkv_chunked`` (the oracle via
``ref.wkv6_ref`` is the plain sequential scan).

VMEM per step (chunk=128, hd=64): 4 inputs (128, 64) f32 + S (64, 64) +
scores (128, 128) ≈ 0.3 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ops import tpu_compiler_params


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_ref, *,
                chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)          # (chunk, hd)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)          # (1, hd)
    S = s_ref[...]                            # (hd_k, hd_v)

    logw = jnp.log(jnp.maximum(w, 1e-38))
    cum = jnp.cumsum(logw, axis=0)            # inclusive (chunk, hd)
    cume = cum - logw                         # exclusive
    total = cum[-1:, :]                       # (1, hd)

    # inter-chunk: r_i decayed against carried state
    r_dec = r * jnp.exp(cume)
    inter = jax.lax.dot(r_dec, S, preferred_element_type=jnp.float32)
    # intra-chunk pairwise j < i
    a = r * jnp.exp(cume)
    bmat = k * jnp.exp(-cum)
    scores = jax.lax.dot_general(a, bmat, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    scores = jnp.where(ii > jj, scores, 0.0)
    diag = jnp.sum(r * u * k, axis=1, keepdims=True)      # (chunk, 1)
    intra = jax.lax.dot(scores, v, preferred_element_type=jnp.float32) \
        + diag * v
    o_ref[0] = (inter + intra).astype(o_ref.dtype)

    # advance state: S' = diag(exp(total)) S + sum_j exp(total - cum_j) k_j v_j
    kw = k * jnp.exp(total - cum)
    s_ref[...] = jnp.exp(total).T * S + jax.lax.dot_general(
        kw, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)


def wkv6(r, k, v, w, u, *, chunk: int = 128, interpret: bool = False):
    """r,k,v,w: (B, T, H, hd); u: (H, hd) -> (out (B,T,H,hd) f32, S_final).

    T must be a multiple of ``chunk`` (caller pads).  Final state is not
    returned by the kernel (train path doesn't need it); use the oracle for
    stateful decode.
    """
    b, t, h, hd = r.shape
    assert t % chunk == 0, (t, chunk)
    n_chunks = t // chunk

    def re(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, t, hd)

    rr, kr, vr, wr = re(r), re(k), re(v), re(w)
    ur = jnp.broadcast_to(u[None], (b, h, hd)).reshape(b * h, 1, hd)
    kernel = functools.partial(_wkv_kernel, chunk=chunk)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, hd), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, hd), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, hd), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, 1, hd), lambda bh, ci: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, hd), lambda bh, ci: (bh, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, t, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(rr, kr, vr, wr, ur)
    return out.reshape(b, h, t, hd).transpose(0, 2, 1, 3)
