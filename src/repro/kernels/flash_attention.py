"""Flash attention Pallas TPU kernel (forward).

Tiling: grid (batch*heads, n_q_blocks, n_kv_blocks); the kv axis is the
innermost (sequential) dimension so the online-softmax state lives in VMEM
scratch across kv iterations.  Block shapes are MXU-aligned (q/kv block x
head_dim, multiples of 128 where the head_dim allows).  Causal and
sliding-window masking happen on block indices first (whole-block skip) and
lane indices second.

VMEM budget per step: q (bq, hd) + k,v (bk, hd) + scores (bq, bk) f32 +
acc (bq, hd) f32 + m,l (bq,) — e.g. bq=bk=512, hd=128: ~2.4 MB, well under
the ~16 MB/core VMEM of a v5e.

The pure-jnp oracle is ``repro.models.layers._chunked_attention`` /
``ref.attention_ref``; tests sweep shapes/dtypes against it with
interpret=True.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ops import tpu_compiler_params

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  causal: bool, window: int, bq: int, bk: int, n_kv: int,
                  sm_scale: float, seq_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    k_start = ki * bk
    # whole-block skip: block fully masked out?
    run = True
    if causal:
        run = k_start <= q_start + bq - 1
    # (windows can't whole-block skip the lower side without dynamic grids;
    # lane masking below handles it)

    def body():
        q = q_ref[0].astype(jnp.float32) * sm_scale          # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                     # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < seq_len
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        v = v_ref[0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        pl.when(k_start <= q_start + bq - 1)(body)
    else:
        body()

    @pl.when(ki == n_kv - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = out.astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 256, block_k: int = 256,
                    interpret: bool = False):
    """q: (B, Tq, H, hd); k, v: (B, Tk, H, hd) (kv heads pre-repeated).

    Returns (B, Tq, H, hd).  Tq/Tk are padded to block multiples internally.
    """
    b, tq, h, hd = q.shape
    tk = k.shape[1]
    bq = min(block_q, max(tq, 16))
    bk = min(block_k, max(tk, 16))
    pq = (bq - tq % bq) % bq
    pk = (bk - tk % bk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    # (B, T, H, hd) -> (B*H, T, hd)
    qr = q.transpose(0, 2, 1, 3).reshape(b * h, tq + pq, hd)
    kr = k.transpose(0, 2, 1, 3).reshape(b * h, tk + pk, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(b * h, tk + pk, hd)
    n_q = (tq + pq) // bq
    n_kv = (tk + pk) // bk
    kernel = functools.partial(
        _flash_kernel, causal=causal, window=window, bq=bq, bk=bk, n_kv=n_kv,
        sm_scale=1.0 / math.sqrt(hd), seq_len=tk)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, tq + pq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qr, kr, vr)
    out = out[:, :tq].reshape(b, h, tq, hd).transpose(0, 2, 1, 3)
    return out
