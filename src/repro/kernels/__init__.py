"""Pallas TPU kernels (pl.pallas_call + BlockSpec VMEM tiling) with jit'd
dispatch (ops.py) and pure-jnp oracles (ref.py)."""
