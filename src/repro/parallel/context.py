"""Context-parallel (CP) ring attention: shard the SEQUENCE axis on
ppermute KV rings.

Tensor-MP (``parallel.collectives``) splits parameters and pipeline-MP
splits layers; neither touches the axis that actually explodes for
long-context workloads.  CP keeps the residual stream sequence-sharded
across the ring — every device holds T/m query rows for the whole layer
stack — and runs attention itself as a ring: the KV shards rotate around
a ``ppermute`` ring while each device's flash attention consumes the
in-flight block, folding it into the online-softmax (m, l, acc) state it
already keeps, exactly the merge rule of
``models.layers.merge_softmax_stats``.  No tensor of global sequence
length is ever materialized on any chip.

Ring schedule (m = 4 devices; payload at step s on device j is KV block
``src = (j - s) mod m``, sent to j+1 WHILE the local partial attention
consumes it)::

        s:    0       1       2       3
      j=0:  KV0·A   KV3·A   KV2·A   KV1·A     A = online-softmax fold
      j=1:  KV1·A   KV0·A   KV3·A   KV2·A     into (m, l, acc); step 0
      j=2:  KV2·A   KV1·A   KV0·A   KV3·A     is the diagonal block, so
      j=3:  KV3·A   KV2·A   KV1·A   KV0·A     every query is live first

Causal masking skips WHOLE remote blocks by ring distance: block ``src``
is strictly-future iff ``src > j``, so device j only computes ``j + 1``
of its m hops (the block is still forwarded on the ring — the transfer
is overlapped anyway, the matmuls are what's saved; same trick for
blocks entirely left of a sliding window).  The backward is a custom
vjp running the REVERSE ring: kb/vb rotate as in the forward while the
dK/dV accumulators ride the ring one hop per step, landing home on their
owner after m hops with every device's contribution summed.

Per-hop cost (GQA: the ring carries the UN-repeated Hkv heads; B batch,
t = T/m local rows, e bytes/elem, bw = per-hop link bandwidth, a =
per-hop latency; compare ``core.comm.cp_ring_time``)::

    ==================  ========================  =======================
    path                wire bytes per chip       exposed time
    ==================  ========================  =======================
    all-gather K,V      2 (m-1)/m * B_kv          transfer THEN attend
                                                    (nothing overlaps)
    CP ring fwd         (m-1) * 2*B*t*Hkv*hd*e    max(hop attn, hop xfer)
                                                    * (m-1) + (m-1) a
    CP ring bwd         2x fwd (dK/dV ride too)   same, ~2.5x hop flops
    ==================  ========================  =======================

Numerics: all (m, l, acc) state is f32; a fold of a fully-masked row is
exp(NEG_INF - finite) = 0 exactly, and step 0's diagonal block gives
every query a finite max before any remote block arrives, so no
NaN-producing (-inf) - (-inf) ever forms.  ``ring_attention`` is pinned
(fp32 round-off) against the unsharded flash/ref attention — loss AND
grads — in ``tests/test_context_parallel.py``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import NEG_INF, repeat_kv
from repro.parallel.collectives import _ring_perm


def _block_skip(src, j, t_loc: int, causal: bool, window: int):
    """Traced predicate: KV block ``src`` contributes nothing to device
    ``j``'s queries, so the hop's matmuls can be skipped entirely.
    Returns None when no static reason to skip exists."""
    skip = None
    if causal:
        skip = src > j                       # strictly-future block
    if window > 0:
        # block src's newest key is (src+1)*t_loc - 1; the oldest query
        # on j is j*t_loc, which sees keys in (j*t_loc - window, j*t_loc]
        too_old = (src + 1) * t_loc - 1 + window <= j * t_loc
        skip = too_old if skip is None else jnp.logical_or(skip, too_old)
    return skip


def _hop_mask(qpos, kpos, causal: bool, window: int):
    valid = None
    if causal:
        valid = kpos[None, :] <= qpos[:, None]
    if window > 0:
        w = kpos[None, :] > qpos[:, None] - window
        valid = w if valid is None else valid & w
    return valid


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _ring_attn(axis, axis_size, causal, window, q, k, v):
    return _ring_attn_fwd(axis, axis_size, causal, window, q, k, v)[0]


def _ring_attn_fwd(axis, axis_size, causal, window, q, k, v):
    m_st, l_st, acc = _ring_fwd_stats(axis, axis_size, causal, window,
                                      q, k, v)
    l_safe = jnp.maximum(l_st, 1e-30)
    out = (acc / l_safe[..., None]).transpose(0, 2, 1, 3).astype(q.dtype)
    lse = m_st + jnp.log(l_safe)                        # (b,h,t)
    return out, (q, k, v, out, lse)


def _ring_fwd_stats(axis, axis_size, causal, window, q, k, v):
    m = axis_size
    b, t_loc, hq, hd = q.shape
    hkv = k.shape[2]
    n_rep = hq // hkv
    j = lax.axis_index(axis)
    scale = 1.0 / math.sqrt(hd)
    qt = q.astype(jnp.float32).transpose(0, 2, 1, 3) * scale  # (b,h,t,hd)
    qpos = j * t_loc + jnp.arange(t_loc)
    m_st = jnp.full((b, hq, t_loc), NEG_INF, jnp.float32)
    l_st = jnp.zeros((b, hq, t_loc), jnp.float32)
    acc = jnp.zeros((b, hq, t_loc, hd), jnp.float32)
    perm = _ring_perm(m)
    kb, vb = k, v
    for s in range(m):
        src = (j - s) % m
        nxt = ([lax.ppermute(p, axis, perm) for p in (kb, vb)]
               if s < m - 1 else None)                  # send before compute
        kpos = src * t_loc + jnp.arange(t_loc)

        def fold(carry, kb=kb, vb=vb, kpos=kpos):
            m0, l0, a0 = carry
            kr = repeat_kv(kb, n_rep).astype(jnp.float32)
            vr = repeat_kv(vb, n_rep).astype(jnp.float32)
            sc = jnp.einsum("bhqd,bkhd->bhqk", qt, kr)
            valid = _hop_mask(qpos, kpos, causal, window)
            if valid is not None:
                sc = jnp.where(valid[None, None], sc, NEG_INF)
            m1 = jnp.maximum(m0, sc.max(axis=-1))
            p = jnp.exp(sc - m1[..., None])
            corr = jnp.exp(m0 - m1)
            l1 = l0 * corr + p.sum(axis=-1)
            a1 = a0 * corr[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vr)
            return m1, l1, a1

        skip = _block_skip(src, j, t_loc, causal, window)
        if skip is None:
            m_st, l_st, acc = fold((m_st, l_st, acc))
        else:
            m_st, l_st, acc = lax.cond(skip, lambda c: c, fold,
                                       (m_st, l_st, acc))
        if nxt is not None:
            kb, vb = nxt
    return m_st, l_st, acc


def _ring_attn_bwd(axis, axis_size, causal, window, res, dout):
    q, k, v, out, lse = res
    m = axis_size
    b, t_loc, hq, hd = q.shape
    hkv = k.shape[2]
    n_rep = hq // hkv
    j = lax.axis_index(axis)
    scale = 1.0 / math.sqrt(hd)
    qt = q.astype(jnp.float32).transpose(0, 2, 1, 3) * scale
    dot = dout.astype(jnp.float32).transpose(0, 2, 1, 3)  # (b,h,t,hd)
    # D_i = sum_d dout_i * out_i — the softmax-jacobian diagonal term
    dterm = (dot * out.astype(jnp.float32).transpose(0, 2, 1, 3)).sum(-1)
    qpos = j * t_loc + jnp.arange(t_loc)
    perm = _ring_perm(m)
    kb, vb = k, v
    dq = jnp.zeros((b, hq, t_loc, hd), jnp.float32)
    # dK/dV accumulators RIDE the ring: ppermuted after every local
    # update (m hops total) so the block-j accumulator lands back on
    # device j carrying all m devices' contributions
    dkb = jnp.zeros((b, t_loc, hkv, hd), jnp.float32)
    dvb = jnp.zeros((b, t_loc, hkv, hd), jnp.float32)
    for s in range(m):
        src = (j - s) % m
        nxt = ([lax.ppermute(p, axis, perm) for p in (kb, vb)]
               if s < m - 1 else None)                  # send before compute
        kpos = src * t_loc + jnp.arange(t_loc)

        def hop(carry, kb=kb, vb=vb, kpos=kpos):
            dq0, dk0, dv0 = carry
            kr = repeat_kv(kb, n_rep).astype(jnp.float32)
            vr = repeat_kv(vb, n_rep).astype(jnp.float32)
            sc = jnp.einsum("bhqd,bkhd->bhqk", qt, kr)
            valid = _hop_mask(qpos, kpos, causal, window)
            if valid is not None:
                sc = jnp.where(valid[None, None], sc, NEG_INF)
            p = jnp.exp(sc - lse[..., None])            # exact probs
            dv_h = jnp.einsum("bhqk,bhqd->bkhd", p, dot)
            dp = jnp.einsum("bhqd,bkhd->bhqk", dot, vr)
            ds = p * (dp - dterm[..., None])
            dq1 = dq0 + jnp.einsum("bhqk,bkhd->bhqd", ds, kr) * scale
            dk_h = jnp.einsum("bhqk,bhqd->bkhd", ds, qt)  # scale via qt
            # GQA: a kv head's grad sums over its repeat group
            dk1 = dk0 + dk_h.reshape(b, t_loc, hkv, n_rep, hd).sum(3)
            dv1 = dv0 + dv_h.reshape(b, t_loc, hkv, n_rep, hd).sum(3)
            return dq1, dk1, dv1

        skip = _block_skip(src, j, t_loc, causal, window)
        if skip is None:
            dq, dkb, dvb = hop((dq, dkb, dvb))
        else:
            dq, dkb, dvb = lax.cond(skip, lambda c: c, hop, (dq, dkb, dvb))
        dkb, dvb = [lax.ppermute(p, axis, perm) for p in (dkb, dvb)]
        if nxt is not None:
            kb, vb = nxt
    return (dq.transpose(0, 2, 1, 3).astype(q.dtype),
            dkb.astype(k.dtype), dvb.astype(v.dtype))


_ring_attn.defvjp(_ring_attn_fwd, _ring_attn_bwd)


def ring_attention(q, k, v, *, axis: str, axis_size: int,
                   causal: bool = True, window: int = 0):
    """Context-parallel GQA attention over a sequence-sharded ring.

    Runs inside a shard_map.  ``q``: (B, T/m, Hq, hd) this device's query
    rows; ``k``/``v``: (B, T/m, Hkv, hd) this device's KV shard (the ring
    carries the un-repeated Hkv heads).  Returns (B, T/m, Hq, hd), this
    device's output rows.  Forward and backward are chunked ppermute
    rings — the compiled HLO carries no all-gather of K/V in either
    direction.  Loss and grads match unsharded ``layers.attention`` at
    fp32 round-off (pinned in tests).
    """
    if axis_size <= 1:
        from repro.models.layers import attention
        return attention(q, k, v, causal=causal, window=window)
    return _ring_attn(axis, axis_size, bool(causal), int(window), q, k, v)


def ring_attention_stats(q, k, v, *, axis: str, axis_size: int,
                         causal: bool = True, window: int = 0):
    """Forward-only ring returning the UNNORMALIZED online-softmax stats
    triple ``(m, l, acc)`` in f32 — shapes (B, Hq, T/m), (B, Hq, T/m),
    (B, Hq, T/m, hd) — mergeable with other partials via
    ``models.layers.merge_softmax_stats``.  This is the serve
    chunked-prefill building block: the chunk's in-chunk attention rides
    the ring (positions are chunk-relative; causal/window masks compare
    q-k DIFFERENCES so a per-request absolute offset cancels), while the
    KV-cache contribution is computed locally per device and merged in
    afterwards.  Inference-path only (no custom_vjp)."""
    return _ring_fwd_stats(axis, axis_size, bool(causal), int(window),
                           q, k, v)


def gathered_attention(q, k, v, *, axis: str, axis_size: int,
                       causal: bool = True, window: int = 0):
    """All-gather-then-attend baseline: reassemble the FULL K/V on every
    device, then run plain attention on the local query rows.  This is
    what GSPMD lowers a sequence-sharded attention to; it exists as the
    benchmark/HLO-contrast foil for ``ring_attention`` (its HLO contains
    the monolithic all-gather the ring avoids)."""
    from repro.models.layers import attention
    if axis_size <= 1:
        return attention(q, k, v, causal=causal, window=window)
    j = lax.axis_index(axis)
    kg = lax.all_gather(k, axis, axis=1, tiled=True)
    vg = lax.all_gather(v, axis, axis=1, tiled=True)
    return attention(q, kg, vg, causal=causal, q_start=j * q.shape[1],
                     window=window)
