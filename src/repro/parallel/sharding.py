"""Divisibility-aware sharding-rule engine: param path -> PartitionSpec.

Implements the Megatron-style tensor-MP decomposition per architecture family
(DESIGN.md §4): attention heads / FFN hidden / experts / vocab on the model
axis, with automatic fallback to replication whenever a dim is not divisible
by the axis size (e.g. smollm's 15 heads on a 16-way axis), and optional
ZeRO-style sharding of the remaining large dim over the DP axes.

Pipeline plans (``plan.is_pipeline``) switch to **stage-dim** rules instead:
the stacked layer dim is sharded over the model axis (per-stage parameter
residency, matching ``parallel.pipeline.stack_to_stages``), embed/head stay
replicated across stages.  ``residual_store_spec`` gives the matching
stage-dim layout of the scheduled runtime's activation store (the
``pipeline_value_and_grad`` residual stash): slots are stage-local, the
micro-batch dim shards over the DP axes.

Context plans (``plan.mp_kind == "context"``) replicate every parameter
across the model axis: the axis carries the sequence-sharded KV ring
(``parallel.context``), so only the batch/fsdp rules engage.
"""
from __future__ import annotations

import math
import warnings
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.parallel.plan import ParallelPlan


def _axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


class ShardingRules:
    """Assigns PartitionSpecs to a model's param pytree and its inputs."""

    def __init__(self, cfg, mesh, plan: ParallelPlan):
        self.cfg = cfg
        self.mesh = mesh
        self.plan = plan
        self.ms = plan.model_axis
        if plan.mp_kind == "context":
            # Context parallelism sequence-shards activations on the model
            # axis (parallel.context KV ring) but keeps every parameter
            # REPLICATED across it — only the batch/fsdp rules apply.
            self.ms = None
        self.msz = _axis_size(mesh, self.ms) if self.ms else 1
        self.fs = plan.fsdp_axes or None
        self.fsz = _axis_size(mesh, self.fs) if self.fs else 1
        self.batch_axes = tuple(plan.dp_axes)
        self._path: Tuple[str, ...] = ()
        self._warned = set()

    # -- helpers ----------------------------------------------------------
    def _m(self, dim: int, head_groups: Optional[int] = None):
        """model axis if divisible (and head-aligned when head_groups given).

        A rule that *wanted* the model axis but cannot divide falls back to
        replication — silently amplifying per-device memory and compute by
        the whole axis size (e.g. smollm's 15 heads on a 16-way axis), so the
        fallback warns once per rule, naming the param path and dim."""
        if not self.ms or self.msz == 1:
            return None
        blocked = None
        if dim % self.msz:
            blocked = f"dim {dim}"
        elif head_groups is not None and head_groups % self.msz:
            blocked = f"head groups {head_groups} (dim {dim})"
        if blocked is None:
            return self.ms
        key = (".".join(self._path), dim, head_groups)
        if key not in self._warned:
            self._warned.add(key)
            warnings.warn(
                f"[sharding] {'.'.join(self._path) or '<input>'}: {blocked} "
                f"not divisible by the {self.msz}-way model axis "
                f"{self.ms!r}; replicating this param across tensor-MP "
                f"(per-device memory/compute x{self.msz} for it)",
                stacklevel=3)
        return None

    def _f(self, dim: int):
        if not self.fs or self.fsz == 1 or dim % self.fsz:
            return None
        return self.fs

    def _matmul(self, d_in: int, d_out: int, head_groups=None,
                row_shard: bool = False):
        """Spec for a (d_in, d_out) weight.  Column-sharded on the model axis
        by default (Megatron column-parallel); row_shard => row-parallel
        (output needs a psum, which GSPMD inserts)."""
        if row_shard:
            m = self._m(d_in, head_groups)
            f = self._f(d_out)
            return P(m, f)
        m = self._m(d_out, head_groups)
        f = self._f(d_in)
        return P(f, m)

    # -- per-leaf rule ----------------------------------------------------
    def leaf_spec(self, path: Tuple[str, ...], shape: Tuple[int, ...]):
        cfg = self.cfg
        names = [p for p in path]
        name = names[-1]
        self._path = tuple(str(p) for p in path)
        stacked = "layers" in names  # leading L dim from scan-stacking
        if self.plan.is_pipeline:
            return self._pipeline_spec(stacked, shape)
        core = shape[1:] if stacked else shape
        spec = self._leaf_spec_core(names, name, core)
        if stacked:
            spec = P(None, *spec)
        return spec

    def _pipeline_spec(self, stacked: bool, shape: Tuple[int, ...]):
        """Pipeline plans shard by **stage residency**, not tensor-MP dims:
        the stacked layer dim splits into contiguous blocks of L/S layers
        per stage (exactly the ``stack_to_stages`` v=1 layout), so the model
        axis shards dim 0 of every stacked leaf — ``memory_analysis`` then
        reports per-stage parameter residency instead of naively replicating
        (or tensor-sharding) the whole stack on every stage.  Embed/head and
        non-divisible stacks stay replicated across stages; ZeRO/fsdp over
        the DP axes still applies to a remaining divisible dim."""
        nd = len(shape)
        if nd == 0:
            return P()
        spec = [None] * nd
        lo = 0
        if stacked and self.ms and self.msz > 1 and shape[0] % self.msz == 0:
            spec[0] = self.ms
            lo = 1
        if self.fs and self.fsz > 1:
            for i in range(nd - 1, lo - 1, -1):      # prefer trailing dims
                if shape[i] % self.fsz == 0:
                    spec[i] = self.fs
                    break
        return P(*spec)

    def _leaf_spec_core(self, names, name, shape):
        cfg = self.cfg
        nd = len(shape)
        if nd <= 1:
            if nd == 1 and name in ("D", "dt_bias") and self._m(shape[0]):
                return P(self.ms)
            return P()
        # embeddings: vocab rows on model axis (Megatron vocab-parallel)
        if name in ("embed", "src_embed", "tgt_embed", "pos_embed"):
            if name == "pos_embed":
                return P(None, None)
            return P(self._m(shape[0]), self._f(shape[1]))
        if name in ("lm_head", "head", "fc"):
            return P(self._f(shape[0]), self._m(shape[1]))
        # MoE expert banks: (E, d, ff) / (E, ff, d) — expert-parallel on model
        if "moe" in names:
            if name in ("wi", "wg") and nd == 3:
                return P(self._m(shape[0]), None, self._f(shape[2]))
            if name == "wo" and nd == 3:
                return P(self._m(shape[0]), self._f(shape[1]), None)
            if name == "router":
                return P(None, None)
            if "shared" in names:  # shared experts: plain TP MLP
                if name in ("wi", "wg"):
                    return P(self._f(shape[0]), self._m(shape[1]))
                return P(self._m(shape[0]), self._f(shape[1]))
        # attention
        if "attn" in names or "xattn" in names:
            if name == "wq":
                return self._matmul(*shape, head_groups=cfg.n_heads)
            if name in ("wk", "wv"):
                return self._matmul(*shape, head_groups=cfg.n_kv_heads)
            if name == "wo":
                return self._matmul(*shape, head_groups=cfg.n_heads,
                                    row_shard=True)
        # rwkv time-mix / channel-mix
        if "tm" in names:
            heads = cfg.d_model // (cfg.head_dim or 64)
            if name in ("wr", "wk", "wv", "wg"):
                return self._matmul(*shape, head_groups=heads)
            if name == "wo":
                return self._matmul(*shape, head_groups=heads, row_shard=True)
            if name in ("wa1", "wa2"):
                return P(None, None)
        if "cm" in names:
            if name == "wk":
                return self._matmul(*shape)
            if name == "wv":
                return self._matmul(*shape, row_shard=True)
            if name == "wr":
                return self._matmul(*shape)
        # ssm (mamba)
        if "ssm" in names or name in ("in_proj", "x_proj", "dt_proj",
                                      "out_proj", "conv_w", "A_log"):
            if name == "in_proj":
                return self._matmul(*shape)
            if name == "conv_w":
                return P(None, self._m(shape[1]))
            if name == "x_proj":   # (di, dt_rank + 2 ds): row-parallel
                return P(self._m(shape[0]), None)
            if name == "dt_proj":
                return P(None, self._m(shape[1]))
            if name == "A_log":
                return P(self._m(shape[0]), None)
            if name == "out_proj":
                return self._matmul(*shape, row_shard=True)
        # mlp
        if name in ("wi", "wg"):
            return self._matmul(*shape)
        if name == "wo":
            return self._matmul(*shape, row_shard=True)
        # lstm cells: column-shard gate projections, row-shard the projection
        if name in ("wx", "wh"):
            return P(self._f(shape[0]), self._m(shape[1]))
        if name == "wp":
            return self._matmul(*shape, row_shard=True)
        if name == "w" and nd == 4:  # conv HWIO: shard output channels
            return P(None, None, None, self._m(shape[3]))
        if name == "attn_q":
            return P(self._f(shape[0]), None)
        return P(*([None] * nd))

    def residual_store_spec(self, ndim: int):
        """Stage-dim spec of the scheduled pipeline runtime's activation
        store viewed as a logical (n_stages, n_slots, mb, ...) array with
        ``ndim`` dims: per-stage slots on the model axis (each device owns
        exactly its ``plan_scheduled_runtime`` slot file), the micro-batch
        dim sharded over the DP axes — the layout
        ``pipeline_value_and_grad`` carries inside its shard_map scan."""
        if ndim < 3:
            raise ValueError(f"store is (stages, slots, mb, ...); ndim={ndim}")
        b = self.batch_axes if _axis_size(self.mesh, self.batch_axes) > 1 \
            else None
        return P(self.ms, None, b, *([None] * (ndim - 3)))

    # -- public API --------------------------------------------------------
    def params_specs(self, params_shape):
        """pytree of PartitionSpec matching a params shape-pytree."""
        def walk(path, node):
            if isinstance(node, dict):
                return {k: walk(path + (k,), v) for k, v in node.items()}
            if isinstance(node, (list, tuple)):
                t = [walk(path + (str(i),), v) for i, v in enumerate(node)]
                return type(node)(t)
            return self.leaf_spec(path, node.shape)

        return walk((), params_shape)

    def params_shardings(self, params_shape):
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            self.params_specs(params_shape),
                            is_leaf=lambda x: isinstance(x, P))

    def opt_specs(self, params_shape, opt_shape):
        """PartitionSpec pytree for an optimizer-state shape-pytree.

        Optimizer state trees mirror the params tree under wrapper keys
        ("m", "v", "acc"), possibly with trailing accumulator keys ("vr" /
        "vc" for adafactor).  Each opt leaf's spec resolves by PATH: strip
        leading wrapper keys until the remainder resolves inside the params
        spec tree, then derive factored-accumulator specs from the param's
        spec.  Used by ``train.steps.shardings_for`` and by the elastic
        resume path (restoring a checkpoint onto a different mesh needs the
        full TrainState's shardings, optimizer state included)."""
        p_spec = self.params_specs(params_shape)

        def resolve(path, leaf):
            keys = [getattr(p, "key", getattr(p, "idx", None)) for p in path]
            for start in range(len(keys)):
                node = p_spec
                consumed = 0
                for k in keys[start:]:
                    if isinstance(node, dict) and k in node:
                        node = node[k]
                        consumed += 1
                    elif isinstance(node, (list, tuple)) and str(k).isdigit() \
                            and int(k) < len(node):
                        node = node[int(k)]
                        consumed += 1
                    else:
                        break
                if isinstance(node, P):
                    rest = keys[start + consumed:]
                    if not rest:
                        return node if len(node) == len(leaf.shape) \
                            else P(*([None] * len(leaf.shape)))
                    if rest == ["vr"]:      # adafactor row accumulator
                        return P(*node[:-1]) if len(node) else P()
                    if rest == ["vc"]:      # adafactor col accumulator
                        return P(*node[:-2], node[-1]) if len(node) >= 2 \
                            else P()
                    if rest == ["v"]:
                        return node
            return P(*([None] * len(leaf.shape)))

        flat, tree = jax.tree_util.tree_flatten_with_path(opt_shape)
        return tree.unflatten([resolve(p, l) for p, l in flat])

    def batch_specs(self, batch_shape):
        """Inputs: batch dim over dp axes (when divisible), rest replicated."""
        bax = self.batch_axes
        bsz = _axis_size(self.mesh, bax)

        def spec(leaf):
            if leaf.shape and leaf.shape[0] % bsz == 0 and leaf.shape[0] > 0 and bsz > 1:
                return P(bax, *([None] * (len(leaf.shape) - 1)))
            return P(*([None] * len(leaf.shape)))

        return jax.tree.map(spec, batch_shape)

    def cache_specs(self, cache_shape):
        """Decode caches: (L, B, len, KV, hd) — batch over dp axes when it
        divides, KV heads over model when they divide; recurrent states shard
        their channel dim on model."""
        bax = self.batch_axes
        bsz = _axis_size(self.mesh, bax)
        cfg = self.cfg

        def spec(path, leaf):
            name = path[-1] if path else ""
            sh = leaf.shape
            self._path = tuple(str(p) for p in path)
            if name == "pos":
                return P()
            b_ok = len(sh) > 1 and sh[1] % bsz == 0 and bsz > 1
            b = bax if b_ok else None
            if name in ("k", "v", "xk", "xv"):
                kvm = self._m(sh[3], head_groups=cfg.n_kv_heads)
                # self-attn caches: sequence-shard over the model axis for the
                # flash-decode path (§Perf B.2) when kv heads can't shard;
                # cross-attn (xk/xv, encoder frames) stays head/replicated
                seq_m = None
                if (name in ("k", "v") and kvm is None
                        and sh[2] % self.msz == 0 and sh[2] >= 1024
                        and self.ms):
                    seq_m = self.ms
                return P(None, b, seq_m, kvm, None)
            if name == "wkv_S":
                hm = self._m(sh[2], head_groups=sh[2])
                return P(None, b, hm, None, None)
            if name in ("tm_x", "cm_x"):
                return P(None, b, self._m(sh[2]))
            if name == "ssm_h":
                return P(None, b, self._m(sh[2]), None)
            if name == "ssm_conv":
                return P(None, b, None, self._m(sh[3]))
            return P(*([None] * len(sh)))

        def walk(path, node):
            if isinstance(node, dict):
                return {k: walk(path + (k,), v) for k, v in node.items()}
            return spec(path, node)

        return walk((), cache_shape)

    def batch_shardings(self, batch_shape):
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            self.batch_specs(batch_shape),
                            is_leaf=lambda x: isinstance(x, P))
