from repro.parallel.plan import ParallelPlan, plan_degrees
from repro.parallel.pipeline import (PipelineSchedule, SCHEDULE_KINDS,
                                     ScheduledRuntimePlan, make_schedule,
                                     pipeline_activation_residency,
                                     pipeline_apply, pipeline_bubble_fraction,
                                     pipeline_step_speedup,
                                     pipeline_value_and_grad,
                                     plan_scheduled_runtime, stack_to_stages,
                                     stages_to_stack)
from repro.parallel.sharding import ShardingRules

__all__ = ["ParallelPlan", "plan_degrees", "PipelineSchedule",
           "SCHEDULE_KINDS", "ScheduledRuntimePlan", "make_schedule",
           "pipeline_apply", "pipeline_bubble_fraction",
           "pipeline_activation_residency", "pipeline_step_speedup",
           "pipeline_value_and_grad", "plan_scheduled_runtime",
           "stack_to_stages", "stages_to_stack", "ShardingRules"]
