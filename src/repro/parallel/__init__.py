from repro.parallel.plan import ParallelPlan, plan_degrees
from repro.parallel.pipeline import (PipelineSchedule, SCHEDULE_KINDS,
                                     make_schedule,
                                     pipeline_activation_residency,
                                     pipeline_apply, pipeline_bubble_fraction,
                                     pipeline_step_speedup, stack_to_stages)
from repro.parallel.sharding import ShardingRules

__all__ = ["ParallelPlan", "plan_degrees", "PipelineSchedule",
           "SCHEDULE_KINDS", "make_schedule", "pipeline_apply",
           "pipeline_bubble_fraction", "pipeline_activation_residency",
           "pipeline_step_speedup", "stack_to_stages", "ShardingRules"]
