from repro.parallel.plan import ParallelPlan, plan_degrees
from repro.parallel.pipeline import (pipeline_apply, pipeline_step_speedup,
                                     stack_to_stages)
from repro.parallel.sharding import ShardingRules

__all__ = ["ParallelPlan", "plan_degrees", "pipeline_apply",
           "pipeline_step_speedup", "stack_to_stages", "ShardingRules"]
