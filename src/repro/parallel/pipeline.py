"""GPipe-style pipeline model-parallelism via shard_map + ppermute.

The paper implements MP for GNMT/BigLSTM as pipeline parallelism (§4.4); on
TPU the idiomatic equivalent streams micro-batches through mesh-axis stages
with ``jax.lax.ppermute`` (DESIGN.md §3).  ``ParallelPlan(mp_kind="pipeline")``
selects this runtime; tests prove pipeline == sequential stacking bit-for-bit
(fp32) and the fig5/table1 benchmarks use its analytic bubble model
(t_pipe = (n_micro + n_stages - 1) / n_micro / n_stages of sequential).

Schedule: micro-batch m enters stage s at tick m + s; total ticks
T = n_micro + n_stages - 1; the bubble fraction is (n_stages-1)/T.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.jaxcompat import shard_map


def pipeline_apply(mesh, axis: str, stage_fn: Callable, stage_params, x,
                   n_micro: int, batch_axes=()):
    """Run ``x`` through a layer stack partitioned into stages over ``axis``.

    stage_params: pytree with leading dim (n_stages, layers_per_stage, ...).
    stage_fn(params_one_stage, x) -> y applies one stage's layers.
    x: (B, ...) with B divisible by n_micro (and by the batch_axes sharding).
    Returns y with x's shape.
    """
    n_stages = mesh.shape[axis]
    b = x.shape[0]
    assert b % n_micro == 0, (b, n_micro)
    mb = b // n_micro
    xm = x.reshape((n_micro, mb) + x.shape[1:])
    bspec = P(*( (batch_axes,) if batch_axes else (None,) ))

    def inner(params_local, xm_local):
        # params_local: (1, layers_per_stage, ...) — this stage's slice
        params_local = jax.tree.map(lambda a: a[0], params_local)
        stage = jax.lax.axis_index(axis)
        T = n_micro + n_stages - 1
        right = [(i, i + 1) for i in range(n_stages - 1)]
        state0 = jnp.zeros_like(xm_local[0])
        outs0 = jnp.zeros_like(xm_local)

        def tick(carry, t):
            state, outs = carry
            inj = xm_local[jnp.clip(t, 0, n_micro - 1)]
            inp = jnp.where(stage == 0, inj, state)
            y = stage_fn(params_local, inp)
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            write = (stage == n_stages - 1) & (t >= n_stages - 1)
            outs = outs.at[out_idx].set(jnp.where(write, y, outs[out_idx]))
            state = jax.lax.ppermute(y, axis, right)
            return (state, outs), None

        (state, outs), _ = jax.lax.scan(tick, (state0, outs0), jnp.arange(T))
        # outputs live on the last stage only; replicate across the axis
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    p_specs = jax.tree.map(lambda _: P(axis), stage_params)
    x_spec = P(None, bspec[0], *([None] * (x.ndim - 1)))
    out = shard_map(inner, mesh=mesh, in_specs=(p_specs, x_spec),
                    out_specs=x_spec)(stage_params, xm)
    return out.reshape(x.shape)


def stack_to_stages(stacked_params, n_stages: int):
    """(L, ...) stacked layer params -> (n_stages, L / n_stages, ...)."""
    def re(a):
        L = a.shape[0]
        assert L % n_stages == 0, (L, n_stages)
        return a.reshape((n_stages, L // n_stages) + a.shape[1:])

    return jax.tree.map(re, stacked_params)


def pipeline_bubble_fraction(n_micro: int, n_stages: int) -> float:
    """Idle fraction of the GPipe schedule — the analytic SU^M input for
    pipeline-MP in the planner (per-step speedup = m * (1 - bubble))."""
    return (n_stages - 1) / (n_micro + n_stages - 1)


def pipeline_step_speedup(m: int, n_micro: int,
                          comm_fraction: float = 0.0) -> float:
    """SU^M of m-stage pipelining with n_micro micro-batches: perfect split
    minus bubble minus inter-stage activation transfer overhead."""
    if m <= 1:
        return 1.0
    eff = 1.0 - pipeline_bubble_fraction(n_micro, m)
    return m * eff / (1.0 + comm_fraction)
