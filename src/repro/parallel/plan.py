"""ParallelPlan: the executable description of a hybrid DP x MP strategy.

This is the object the paper's planner (repro.core.planner) emits and the
runtime consumes: which mesh axes carry data parallelism (the paper's N), which
axis carries model parallelism (the paper's M), and whether parameters /
optimizer state are additionally sharded over the DP axes (ZeRO-style "fsdp" —
a beyond-paper addition required to *fit* 2025-scale models; the paper-faithful
baseline keeps it off).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax


@dataclasses.dataclass(frozen=True)
class ParallelPlan:
    dp_axes: Tuple[str, ...] = ("data",)   # batch sharded over these (paper's N)
    model_axis: Optional[str] = "model"    # tensor/pipeline MP axis (paper's M)
    fsdp_axes: Tuple[str, ...] = ()        # params/opt additionally sharded here
    # "tensor": Megatron head/FFN sharding over model_axis.
    # "pipeline": model_axis carries pipeline stages.
    # "context": model_axis carries the sequence-sharded KV ring
    #   (parallel.context) — params stay REPLICATED across it; the residual
    #   stream is sequence-sharded and attention rotates KV on a ppermute
    #   ring.  Mutually exclusive with the overlapped tensor-MP comm runtime
    #   (the ring IS the comm schedule).
    mp_kind: str = "tensor"                # "tensor" | "pipeline" | "context"
    # For mp_kind="tensor": delayed-gradient accumulation count (§4.2).
    # For mp_kind="pipeline": pipeline micro-batches fed through the stages.
    microbatches: int = 1
    # Pipeline schedule ("gpipe" | "1f1b" | "interleaved") and, for
    # interleaved, the virtual layer chunks per device (v).
    schedule: str = "gpipe"
    virtual_stages: int = 1
    # Which pipeline runtime executes the schedule: "scheduled" runs the
    # complete fwd+bwd WorkUnit table by hand (pipeline_value_and_grad —
    # realizes the schedule's activation residency, e.g. 1f1b's min(K, S));
    # "ad" runs the forward placement through lax.scan and lets jax AD
    # synthesize the backward (GPipe-like K-micro residency regardless of
    # schedule; kept for bit-for-bit differential testing).
    runtime: str = "scheduled"
    # Which collective runtime carries the tensor-MP matmuls and the DP
    # gradient sync: "gspmd" leaves both to the partitioner (monolithic
    # all-reduces, the escape hatch); "overlapped" routes the Megatron
    # row/column matmuls through parallel.collectives' chunked ppermute
    # rings and the DP grad exchange through the bucketed
    # reduce-scatter/all-gather sync.
    comm_runtime: str = "gspmd"
    comm_chunks: int = 1          # ring chunks per shard for "overlapped"
    remat: bool = True

    PIPE_RUNTIMES = ("scheduled", "ad")
    COMM_RUNTIMES = ("gspmd", "overlapped")
    MP_KINDS = ("tensor", "pipeline", "context")

    def __post_init__(self):
        if self.mp_kind not in self.MP_KINDS:
            raise ValueError(f"unknown mp_kind {self.mp_kind!r}; "
                             f"expected one of {self.MP_KINDS}")
        if self.runtime not in self.PIPE_RUNTIMES:
            raise ValueError(f"unknown pipeline runtime {self.runtime!r}; "
                             f"expected one of {self.PIPE_RUNTIMES}")
        if self.comm_runtime not in self.COMM_RUNTIMES:
            raise ValueError(f"unknown comm runtime {self.comm_runtime!r}; "
                             f"expected one of {self.COMM_RUNTIMES}")
        if self.comm_chunks < 1:
            raise ValueError(f"comm_chunks must be >= 1, "
                             f"got {self.comm_chunks}")
        if self.mp_kind == "context" and self.comm_runtime == "overlapped":
            raise ValueError(
                "mp_kind='context' already schedules its own KV ring; "
                "it cannot combine with comm_runtime='overlapped' "
                "(use the default 'gspmd' for everything outside the ring)")

    @property
    def is_pipeline(self) -> bool:
        return self.mp_kind == "pipeline" and self.model_axis is not None

    @property
    def is_context(self) -> bool:
        return self.mp_kind == "context" and self.model_axis is not None

    def describe(self, mesh) -> str:
        dp = 1
        for a in self.dp_axes:
            dp *= mesh.shape[a]
        mp = mesh.shape[self.model_axis] if self.model_axis else 1
        unit = "micro" if self.is_pipeline else "accum"
        sched = ""
        if self.is_pipeline:
            v = f" v={self.virtual_stages}" if self.virtual_stages > 1 else ""
            sched = f" [{self.schedule}{v}, {self.runtime} runtime]"
        elif self.is_context:
            sched = " [kv ring]"
        comm = ""
        if self.comm_runtime != "gspmd":
            c = f" c={self.comm_chunks}" if self.comm_chunks > 1 else ""
            comm = f" [{self.comm_runtime} comm{c}]"
        return (f"{dp}-way DP x {mp}-way {self.mp_kind} MP{sched}{comm}"
                f"{' +fsdp' if self.fsdp_axes else ''}"
                f"{f' x{self.microbatches} {unit}' if self.microbatches > 1 else ''}")


def plan_degrees(plan: ParallelPlan, mesh) -> Tuple[int, int]:
    """(N, M) = (data-parallel ways, model-parallel ways) of plan on mesh."""
    n = 1
    for a in plan.dp_axes:
        n *= mesh.shape[a]
    m = mesh.shape[plan.model_axis] if plan.model_axis else 1
    return n, m


def serve_plan(tp: int, *, comm_runtime: str = "overlapped",
               comm_chunks: int = 1) -> ParallelPlan:
    """The decode-mesh plan for one serving replica: slots shard over
    ``data``, the layer matmuls over a ``tp``-way ``model`` axis riding the
    collective rings (tp == 1 degenerates to a single-device replica)."""
    return ParallelPlan(
        dp_axes=("data",),
        model_axis="model" if tp > 1 else None,
        mp_kind="tensor",
        comm_runtime=comm_runtime if tp > 1 else "gspmd",
        comm_chunks=comm_chunks,
        remat=False)


PAPER_BASELINE = ParallelPlan()                                  # DP x tensor-MP
PAPER_DP_ONLY = ParallelPlan(model_axis=None)                    # pure DP
OPTIMIZED = ParallelPlan(fsdp_axes=("data",))                    # + ZeRO-3
PAPER_PIPELINE = ParallelPlan(mp_kind="pipeline", microbatches=4)  # §4.4 GPipe
CONTEXT = ParallelPlan(mp_kind="context")                        # DP x KV-ring CP
