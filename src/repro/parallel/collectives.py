"""Overlap-scheduled collective subsystem: chunked collective-matmul rings for
tensor-MP and bucketed reduce-scatter gradient sync for DP.

GSPMD lowers the Megatron row/column-parallel matmul pair to *monolithic*
collectives: a blocking all-reduce after every row-parallel matmul (forward
and backward), with zero overlap between the transfer and the partial matmuls
that feed it.  This module replaces that hot path with hand-scheduled
``ppermute`` rings of shard-sized chunks — the collective-matmul decomposition
— so partial matmuls run concurrently with in-flight transfers, plus a
ZeRO-style bucketed reduce-scatter/all-gather gradient sync for the DP axes.
``ParallelPlan(comm_runtime="overlapped")`` selects this runtime;
``"gspmd"`` (the default) is the escape hatch.

Collective-matmul rings (m shards on the model axis, c chunks per shard)
=======================================================================

``all_gather_matmul``  (column-parallel: x seq-sharded, W column-sharded)::

    y[:, T] = all_gather(x) @ W_loc     decomposed as, on device j at step s
    (payload: the x-chunk originally resident on shard (j - s) mod m):

        s:   0      1      2      3                       (m = 4)
      j=0:  x0@W   x3@W   x2@W   x1@W      each step the held chunk is
      j=1:  x1@W   x0@W   x3@W   x2@W      matmul'd into its output rows
      j=2:  x2@W   x1@W   x0@W   x3@W      WHILE the ppermute of that chunk
      j=3:  x3@W   x2@W   x1@W   x0@W      to shard j+1 is in flight

``matmul_reduce_scatter``  (row-parallel: W row-sharded, output seq-scattered)::

    y_j[T/m] = rows j of sum_i (h_i @ W_i)   as a reduce ring: the partial
    accumulator for chunk (j - 1 - s) mod m arrives at device j at step s,
    j's own partial matmul for that chunk is added, and the sum moves on;
    after m-1 hops device j holds the fully-reduced chunk j.

Both run forward AND backward (``jax.custom_vjp``): the backward of
``all_gather_matmul`` is a ``matmul_reduce_scatter`` of the output cotangent
(for dx) fused with a second gather ring (for dW, Megatron-style activation
re-gather instead of stashing the gathered x); the backward of
``matmul_reduce_scatter`` is one gather ring producing dh and dW together.

Overlap model / chunk-count tradeoff (B bytes over the ring, c chunks/shard,
alpha = per-hop launch+rendezvous latency, bw = per-hop bandwidth):

    ==================  =====================  ===========================
    path                wire bytes per chip    exposed (non-overlap) time
    ==================  =====================  ===========================
    GSPMD all-reduce    2 (m-1)/m * B          2 (m-1)/m * B/bw + (m-1) a
    ring all-gather     (m-1)/m * B            max(chunk_mm, chunk_xfer)
      / reduce-scatter                           + c (m-1) a  (fill/drain)
    ==================  =====================  ===========================

Larger c => finer pipelining of the first/last chunk (smaller fill bubble)
but c*(m-1) latency terms; c = 1..2 is right when the per-chunk matmul time
dominates alpha, larger c only pays off for very large shards.  The measured
overlap constant lives in ``core.comm.MEASURED_OVERLAP`` and is calibrated
by ``benchmarks/collective_overlap_sweep.py`` (BENCH_collectives.json).

Bucketed DP gradient sync
=========================

``bucketed_grad_sync`` partitions the flattened gradient pytree (reverse
traversal order — the order the backward retires them) into size-targeted
buckets and issues one ``psum_scatter`` + ``all_gather`` pair per bucket
(ZeRO-style split of the monolithic all-reduce), hierarchically across pods
(reduce-scatter intra-pod, psum across pods, all-gather intra-pod — the
``core.comm.hierarchical_all_reduce_time`` schedule).  Per-bucket collectives
expose the overlap opportunity a single fused all-reduce denies the
scheduler: bucket k's reduce-scatter can run while bucket k+1's gradients
are still being produced by the remaining backward compute.

Everything here executes INSIDE a ``shard_map`` over the mesh; the functions
take the model-axis name and its (static) size explicitly so the ring loops
unroll at trace time.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

# Size target for one DP gradient bucket (torch-DDP-style default: large
# enough to amortize per-collective latency, small enough that several
# buckets are in flight over one backward).
DEFAULT_BUCKET_BYTES = 32 * 1024 * 1024


def _ring_perm(m: int):
    return [(i, (i + 1) % m) for i in range(m)]


def _split_rows(x, chunks: int):
    """Split the second-to-last (row) dim into ``chunks`` equal pieces."""
    t = x.shape[-2]
    if t % chunks:
        raise ValueError(f"chunk count {chunks} does not divide rows {t}")
    return [lax.slice_in_dim(x, i * (t // chunks), (i + 1) * (t // chunks),
                             axis=-2) for i in range(chunks)]


def _flat2(x):
    """(..., T, D) -> (prod(...), T, D) for batch-summed weight grads."""
    return x.reshape((-1,) + x.shape[-2:])


# ---------------------------------------------------------------------------
# all_gather(x) @ W  as a chunked ppermute ring
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _ag_mm(axis, axis_size, chunks, x, w):
    return _ag_mm_fwd(axis, axis_size, chunks, x, w)[0]


def _ag_mm_fwd(axis, axis_size, chunks, x, w):
    m = axis_size
    j = lax.axis_index(axis)
    t_loc = x.shape[-2]
    piece = t_loc // chunks
    out = jnp.zeros(x.shape[:-2] + (t_loc * m, w.shape[-1]),
                    jnp.result_type(x.dtype, w.dtype))
    perm = _ring_perm(m)
    pieces = _split_rows(x, chunks)
    for s in range(m):
        src = (j - s) % m
        nxt = ([lax.ppermute(p, axis, perm) for p in pieces]
               if s < m - 1 else None)                 # send before compute
        for ci, p in enumerate(pieces):
            out = lax.dynamic_update_slice_in_dim(
                out, p @ w, src * t_loc + ci * piece, axis=-2)
        pieces = nxt
    return out, (x, w)


def _ag_mm_bwd(axis, axis_size, chunks, res, dy):
    x, w = res
    m = axis_size
    j = lax.axis_index(axis)
    t_loc = x.shape[-2]
    piece = t_loc // chunks
    # dx: rows of sum_j dy_j @ W_j^T, reduce-scattered back to this shard
    dx = _mm_rs(axis, m, chunks, dy, w.swapaxes(-1, -2))
    # dW = all_gather(x)^T @ dy: re-gather x on a second ring (Megatron-style
    # recompute — stashing the gathered x would m-fold its activation memory)
    dw = jnp.zeros(w.shape, w.dtype)
    perm = _ring_perm(m)
    pieces = _split_rows(x, chunks)
    for s in range(m):
        src = (j - s) % m
        nxt = ([lax.ppermute(p, axis, perm) for p in pieces]
               if s < m - 1 else None)
        for ci, p in enumerate(pieces):
            dy_blk = lax.dynamic_slice_in_dim(
                dy, src * t_loc + ci * piece, piece, axis=-2)
            dw = dw + jnp.einsum("btd,btf->df", _flat2(p),
                                 _flat2(dy_blk)).astype(w.dtype)
        pieces = nxt
    return dx.astype(x.dtype), dw


_ag_mm.defvjp(_ag_mm_fwd, _ag_mm_bwd)


def all_gather_matmul(x, w, *, axis: str, axis_size: int, chunks: int = 1):
    """``all_gather(x, axis) @ w`` as an overlap-scheduled ppermute ring.

    Runs inside a shard_map.  ``x``: (..., T/m, d) sequence-sharded over
    ``axis``; ``w``: (d, F/m) this shard's column slice.  Returns
    (..., T, F/m).  Forward and backward are chunked rings (no monolithic
    all-gather / all-reduce in either direction).
    """
    if axis_size <= 1:
        return x @ w
    if x.shape[-2] % chunks:
        raise ValueError(f"chunks={chunks} must divide the local row count "
                         f"{x.shape[-2]}")
    return _ag_mm(axis, axis_size, chunks, x, w)


def ring_all_gather(x, *, axis: str, axis_size: int, chunks: int = 1):
    """``all_gather(x, axis)`` over the row dim as a chunked ppermute ring.

    The no-matmul sibling of ``all_gather_matmul`` for the one place decode
    genuinely needs the full tensor reassembled (the residual stream before
    the replicated LM head): same ring schedule, each hop's payload is
    written straight into its output rows instead of being matmul'd.
    ``x``: (..., T/m, d) row-sharded over ``axis``; returns (..., T, d).
    Inference-path only (no custom_vjp).
    """
    if axis_size <= 1:
        return x
    if x.shape[-2] % chunks:
        raise ValueError(f"chunks={chunks} must divide the local row count "
                         f"{x.shape[-2]}")
    m = axis_size
    j = lax.axis_index(axis)
    t_loc = x.shape[-2]
    piece = t_loc // chunks
    out = jnp.zeros(x.shape[:-2] + (t_loc * m, x.shape[-1]), x.dtype)
    perm = _ring_perm(m)
    pieces = _split_rows(x, chunks)
    for s in range(m):
        src = (j - s) % m
        nxt = ([lax.ppermute(p, axis, perm) for p in pieces]
               if s < m - 1 else None)                  # send before write
        for ci, p in enumerate(pieces):
            out = lax.dynamic_update_slice_in_dim(
                out, p, src * t_loc + ci * piece, axis=-2)
        pieces = nxt
    return out


# ---------------------------------------------------------------------------
# reduce_scatter(h @ W)  as a chunked ppermute reduce ring
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _mm_rs_vjp(axis, axis_size, chunks, h, w):
    return _mm_rs(axis, axis_size, chunks, h, w)


def _mm_rs(axis, axis_size, chunks, h, w):
    m = axis_size
    j = lax.axis_index(axis)
    t = h.shape[-2]
    t_loc = t // m
    piece = t_loc // chunks
    perm = _ring_perm(m)

    def partial_piece(c, ci):
        blk = lax.dynamic_slice_in_dim(h, c * t_loc + ci * piece, piece,
                                       axis=-2)
        return blk @ w

    # chunk (j-1-s) mod m's accumulator arrives at device j at ring step s
    accs = [partial_piece((j - 1) % m, ci) for ci in range(chunks)]
    for s in range(m - 1):
        accs = [lax.ppermute(a, axis, perm) for a in accs]
        c = (j - 2 - s) % m
        accs = [a + partial_piece(c, ci) for ci, a in enumerate(accs)]
    return jnp.concatenate(accs, axis=-2) if chunks > 1 else accs[0]


def _mm_rs_fwd(axis, axis_size, chunks, h, w):
    return _mm_rs(axis, axis_size, chunks, h, w), (h, w)


def _mm_rs_bwd(axis, axis_size, chunks, res, dy):
    # one gather ring of the (seq-sharded) output cotangent produces both
    # dh = all_gather(dy) @ W^T and dW = h^T @ all_gather(dy)
    h, w = res
    m = axis_size
    j = lax.axis_index(axis)
    t_loc = dy.shape[-2]
    piece = t_loc // chunks
    wt = w.swapaxes(-1, -2)
    dh = jnp.zeros(h.shape, jnp.result_type(dy.dtype, w.dtype))
    dw = jnp.zeros(w.shape, w.dtype)
    perm = _ring_perm(m)
    pieces = _split_rows(dy, chunks)
    for s in range(m):
        src = (j - s) % m
        nxt = ([lax.ppermute(p, axis, perm) for p in pieces]
               if s < m - 1 else None)
        for ci, p in enumerate(pieces):
            start = src * t_loc + ci * piece
            dh = lax.dynamic_update_slice_in_dim(dh, p @ wt, start, axis=-2)
            h_blk = lax.dynamic_slice_in_dim(h, start, piece, axis=-2)
            dw = dw + jnp.einsum("btf,btd->fd", _flat2(h_blk),
                                 _flat2(p)).astype(w.dtype)
        pieces = nxt
    return dh.astype(h.dtype), dw


_mm_rs_vjp.defvjp(_mm_rs_fwd, _mm_rs_bwd)


def matmul_reduce_scatter(h, w, *, axis: str, axis_size: int, chunks: int = 1):
    """``reduce_scatter(h @ w, axis)`` as an overlap-scheduled reduce ring.

    Runs inside a shard_map.  ``h``: (..., T, F/m) this shard's column slice
    of the activations; ``w``: (F/m, d) this shard's row slice.  Returns
    (..., T/m, d): this shard's sequence rows of ``sum_j h_j @ w_j``.  Each
    partial matmul is computed while the previous accumulator hop is in
    flight; the backward is a single gather ring.
    """
    if axis_size <= 1:
        return h @ w
    t_loc = h.shape[-2] // axis_size
    if h.shape[-2] % axis_size:
        raise ValueError(f"rows {h.shape[-2]} not divisible by "
                         f"axis_size {axis_size}")
    if t_loc % chunks:
        raise ValueError(f"chunks={chunks} must divide the per-shard row "
                         f"count {t_loc}")
    return _mm_rs_vjp(axis, axis_size, chunks, h, w)


# ---------------------------------------------------------------------------
# bucketed DP gradient sync (ZeRO-style reduce-scatter + all-gather)
# ---------------------------------------------------------------------------

def grad_bucket_sizes(grads, bucket_bytes: float = DEFAULT_BUCKET_BYTES):
    """Bucket assignment (list of per-bucket leaf counts) for a grad pytree:
    leaves in REVERSE flatten order (the order the backward retires them),
    greedily packed into buckets of at most ``bucket_bytes`` (every bucket
    holds at least one leaf, so oversized leaves get a bucket of their own).
    """
    leaves = jax.tree.leaves(grads)
    sizes = [leaf.size * leaf.dtype.itemsize for leaf in reversed(leaves)]
    buckets, cur, cur_bytes = [], 0, 0
    for s in sizes:
        if cur and cur_bytes + s > bucket_bytes:
            buckets.append(cur)
            cur, cur_bytes = 0, 0
        cur += 1
        cur_bytes += s
    if cur:
        buckets.append(cur)
    return buckets


def bucketed_grad_sync(grads, *, dp_axis: str, dp_size: int,
                       pod_axis: Optional[str] = None,
                       bucket_bytes: float = DEFAULT_BUCKET_BYTES):
    """Sum per-device partial gradients across the DP axes, bucket by bucket.

    Runs inside a shard_map.  Each bucket (reverse-traversal-ordered leaves,
    ``grad_bucket_sizes``) is flattened into one f32 buffer and synced as

        psum_scatter(dp_axis)  ->  [psum(pod_axis)]  ->  all_gather(dp_axis)

    — the ZeRO split of the monolithic all-reduce, hierarchical across pods.
    Issuing one pair per bucket is what lets the scheduler overlap bucket
    k's wire time with the backward compute still producing bucket k+1
    (a single fused all-reduce serializes behind the full backward).
    Returns the fully-summed gradient pytree (identical on every DP rank).
    """
    leaves, treedef = jax.tree.flatten(grads)
    rev = list(reversed(leaves))
    out_rev = []
    i = 0
    for count in grad_bucket_sizes(grads, bucket_bytes):
        group = rev[i:i + count]
        i += count
        flat = jnp.concatenate([g.astype(jnp.float32).ravel() for g in group])
        pad = (-flat.size) % dp_size
        if pad:
            flat = jnp.pad(flat, (0, pad))
        shard = lax.psum_scatter(flat, dp_axis, scatter_dimension=0,
                                 tiled=True)
        if pod_axis is not None:
            shard = lax.psum(shard, pod_axis)
        full = lax.all_gather(shard, dp_axis, axis=0, tiled=True)
        off = 0
        for g in group:
            out_rev.append(full[off:off + g.size].reshape(g.shape)
                           .astype(g.dtype))
            off += g.size
    return jax.tree.unflatten(treedef, list(reversed(out_rev)))
