"""Version bridge for the handful of jax APIs that moved between releases.

The repo targets current jax (``jax.shard_map`` / ``jax.set_mesh`` /
``jax.sharding.AxisType``) but must also run on 0.4.x, where the same
features live under ``jax.experimental.shard_map`` (with ``check_rep``
instead of ``check_vma``), meshes have no axis types, and entering a mesh
context is ``with mesh:``.  All mesh/shard_map construction in this repo
goes through these three wrappers so the difference lives in exactly one
place.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map(..., check_vma=False)`` on new jax,
    ``jax.experimental.shard_map.shard_map(..., check_rep=False)`` on old."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


def make_mesh(shape, axis_names):
    """``jax.make_mesh`` with Auto axis types where the concept exists."""
    try:
        from jax.sharding import AxisType
    except ImportError:
        return jax.make_mesh(shape, axis_names)
    return jax.make_mesh(shape, axis_names,
                         axis_types=(AxisType.Auto,) * len(axis_names))


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict: newer jax returns the
    dict directly, 0.4.x wraps it in a one-element list."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def set_mesh(mesh):
    """Context manager making ``mesh`` the ambient mesh: ``jax.set_mesh`` on
    new jax; on old jax a ``Mesh`` is itself the context manager."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh
