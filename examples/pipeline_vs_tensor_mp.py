"""Pipeline-MP vs tensor-MP on 8 forced host devices: both must produce the
same loss as the single-device reference; prints the collective footprint
difference (the paper treats pipelining as an MP instance — §2).

    PYTHONPATH=src python examples/pipeline_vs_tensor_mp.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core.roofline import parse_collectives  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.parallel.jaxcompat import make_mesh, set_mesh  # noqa: E402
from repro.parallel.pipeline import pipeline_apply, stack_to_stages  # noqa: E402
from repro.parallel.plan import ParallelPlan  # noqa: E402
from repro.parallel.sharding import ShardingRules  # noqa: E402

import dataclasses

cfg = dataclasses.replace(get_config("llama3_2_1b").reduced(), n_layers=8)
api = build_model(cfg, remat=False)
key = jax.random.PRNGKey(0)
params = api.init(key)
batch = {"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab_size,
                                      dtype=jnp.int32),
         "labels": jax.random.randint(key, (8, 32), 0, cfg.vocab_size,
                                      dtype=jnp.int32)}
ref, _ = api.loss_fn(params, batch)
print(f"single-device loss: {float(ref):.6f}")

mesh = make_mesh((2, 4), ("data", "model"))

# --- tensor MP (GSPMD) -------------------------------------------------------
rules = ShardingRules(cfg, mesh, ParallelPlan())
p_sh = rules.params_shardings(jax.eval_shape(api.init, key))
b_sh = rules.batch_shardings(jax.eval_shape(lambda: batch))
with set_mesh(mesh):
    f = jax.jit(lambda p, b: api.loss_fn(p, b)[0], in_shardings=(p_sh, b_sh))
    lowered = f.lower(params, batch)
    tp_loss = f(params, batch)
coll_tp = parse_collectives(lowered.compile().as_text(), default_group=4)
print(f"tensor-MP loss:     {float(tp_loss):.6f}  "
      f"collectives={coll_tp.ops} wire={coll_tp.wire_bytes/2**20:.1f} MiB")

# --- pipeline MP over the layer stack ---------------------------------------
from repro.models import transformer as tf_mod  # noqa: E402
from repro.models import layers as L  # noqa: E402


def stage_fn(stage_params, x):
    def body(x, lp):
        y, _, _ = tf_mod.block_apply(cfg, lp, x, mode="train", window=0,
                                     pos0=0)
        return y, None
    y, _ = jax.lax.scan(body, x, stage_params)
    return y


def pipeline_loss(params, batch):
    x = tf_mod._embed(cfg, params, batch["tokens"])
    stages = stack_to_stages(params["layers"], 4)
    x = pipeline_apply(mesh, "model", stage_fn, stages, x, n_micro=4,
                       batch_axes="data")
    logits = tf_mod._head(cfg, params, x)
    from repro.models.api import cross_entropy
    return cross_entropy(logits, batch["labels"], cfg.vocab_size)


with set_mesh(mesh):
    g = jax.jit(pipeline_loss)
    lowered_p = g.lower(params, batch)
    pp_loss = g(params, batch)
coll_pp = parse_collectives(lowered_p.compile().as_text(), default_group=4)
print(f"pipeline-MP loss:   {float(pp_loss):.6f}  "
      f"collectives={coll_pp.ops} wire={coll_pp.wire_bytes/2**20:.1f} MiB")
assert abs(float(pp_loss) - float(ref)) < 1e-4
assert abs(float(tp_loss) - float(ref)) < 1e-4
print("both MP implementations match the single-device reference.")
