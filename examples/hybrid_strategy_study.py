"""The paper's core artifact as a study: for every assigned architecture,
sweep device budgets, evaluate DP-only vs hybrid (Eq. 4 vs Eq. 5), find the
crossover point, and print the planner's chosen strategy.

    PYTHONPATH=src python examples/hybrid_strategy_study.py
"""
from repro.configs import ARCH_IDS, get_config
from repro.core.analytical import speedup_dp, speedup_hybrid
from repro.core.planner import HybridPlanner, default_epoch_model

BUDGETS = [16, 64, 256, 512, 2048]

print(f"{'arch':24s} {'crossover':>9s}  " +
      "  ".join(f"{d:>11d}" for d in BUDGETS))
print(f"{'':24s} {'(m=2)':>9s}  " +
      "  ".join(f"{'dpxmp':>11s}" for _ in BUDGETS))
for arch in ARCH_IDS:
    cfg = get_config(arch)
    planner = HybridPlanner(cfg, epoch_model=default_epoch_model(cfg),
                            se_perfect=False)
    xo = planner.crossover(m=2)
    cells = []
    for d in BUDGETS:
        c = planner.best(d)
        cells.append(f"{c.dp*c.pods}x{c.mp} ({c.speedup:5.0f})")
    print(f"{arch:24s} {str(xo):>9s}  " + "  ".join(f"{c:>11s}" for c in cells))

print("\nDetail: llama3.2-1b at 512 devices (Eq. 4 vs Eq. 5):")
cfg = get_config("llama3_2_1b")
planner = HybridPlanner(cfg, epoch_model=default_epoch_model(cfg),
                        se_perfect=False)
run = planner.run
for m in (1, 2, 4, 8, 16):
    n = 512 // m
    su = speedup_hybrid(run, n, m)
    print(f"  {n:4d}-way DP x {m:2d}-way MP: SU = {su:8.1f}"
          + ("   <- DP-only (Eq. 4)" if m == 1 else ""))
