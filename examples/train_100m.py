"""End-to-end driver: train a ~100M-parameter llama-family model for a few
hundred steps on the synthetic Markov-LM task, with checkpointing and the
delayed-gradient accumulation from the paper's §4.2.

    PYTHONPATH=src python examples/train_100m.py [--steps 200] [--accum 4]

(CPU-sized end-to-end run; the multi-pod path for the same code is exercised
by ``python -m repro.launch.dryrun``.)
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.data import DataPipeline, make_lm_dataset
from repro.models import build_model
from repro.optim import adamw, warmup_cosine
from repro.parallel.plan import ParallelPlan
from repro.train.loop import LoopConfig, train_loop
from repro.train.steps import init_train_state, make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--accum", type=int, default=1,
                help="delayed-gradient micro-batches (paper §4.2)")
ap.add_argument("--batch", type=int, default=16)
ap.add_argument("--ckpt-dir", default="/tmp/repro_100m")
args = ap.parse_args()

CFG = ModelConfig(
    name="llama-100m", family="dense",
    n_layers=10, d_model=640, n_heads=10, n_kv_heads=5, d_ff=2560,
    vocab_size=32000, source="examples/train_100m.py (llama-family ~100M)")
print(f"params: {CFG.n_params()/1e6:.1f}M")

api = build_model(CFG)
data = make_lm_dataset(vocab=256, seq_len=128, n_items=8192)
print(f"task entropy floor: {data.entropy:.4f} nats/token")

opt = adamw(warmup_cosine(3e-3, 20, args.steps), weight_decay=0.01)
plan = ParallelPlan(microbatches=args.accum)
step = jax.jit(make_train_step(api, opt, plan=plan), donate_argnums=(0,))
state = init_train_state(api, opt, jax.random.PRNGKey(0))

pipeline = DataPipeline(
    lambda e: ({"tokens": jnp.asarray(b["tokens"]) % CFG.vocab_size,
                "labels": jnp.asarray(b["labels"]) % CFG.vocab_size}
               for b in data.epoch(e, args.batch * args.accum)))
res = train_loop(step, state, pipeline,
                 LoopConfig(total_steps=args.steps, log_every=10,
                            ckpt_every=100, ckpt_dir=args.ckpt_dir))
print(f"final loss {res['final_loss']:.4f} after {res['steps']} steps "
      f"({res['wall_s']:.0f}s); floor {data.entropy:.4f}")
