"""Quickstart: the public API in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py

1. pick an assigned architecture config (reduced for CPU),
2. ask the paper's HybridPlanner how to parallelize a 256-chip budget,
3. train a few steps on the synthetic LM task,
4. generate tokens with the serving engine.
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.planner import HybridPlanner, default_epoch_model
from repro.data import make_lm_dataset
from repro.models import build_model
from repro.optim import adamw, warmup_cosine
from repro.serve.engine import ServeEngine
from repro.train.steps import init_train_state, make_train_step

# --- 1. architecture ---------------------------------------------------------
cfg = get_config("llama3_2_1b").reduced()
print(f"arch: {cfg.name} ({cfg.n_layers}L d={cfg.d_model})")

# --- 2. the paper's planner: how should 256 chips be split? ------------------
planner = HybridPlanner(get_config("llama3_2_1b"),
                        epoch_model=default_epoch_model(get_config("llama3_2_1b")),
                        se_perfect=False)
choice = planner.best(256)
print(f"planner: {choice.dp}-way DP x {choice.mp}-way MP "
      f"(SU={choice.speedup:.1f}, SU^M={choice.su_m:.2f}, "
      f"SE_N={choice.se_n:.3f}, E1/EN={choice.epochs_ratio:.3f})")
print(f"crossover (m=2): hybrid first wins at "
      f"{planner.crossover(m=2)} devices")

# --- 3. train ----------------------------------------------------------------
api = build_model(cfg)
data = make_lm_dataset(vocab=64, seq_len=32, n_items=512)
opt = adamw(warmup_cosine(5e-3, 5, 50))
step = jax.jit(make_train_step(api, opt), donate_argnums=(0,))
state = init_train_state(api, opt, jax.random.PRNGKey(0))
for i, batch in enumerate(data.epoch(0, 16)):
    if i >= 30:
        break
    state, m = step(state, {k: jnp.asarray(v) for k, v in batch.items()})
    if i % 10 == 0:
        print(f"step {i:3d} loss {float(m['loss']):.4f} "
              f"(floor {data.entropy:.4f})")

# --- 4. serve ----------------------------------------------------------------
engine = ServeEngine(api, state.params)
prompt = {"tokens": jnp.array([[1, 2, 3, 4, 5, 6, 7, 8]], jnp.int32)}
out = engine.generate(prompt, max_new_tokens=8)
print("generated:", out.tokens[0].tolist())
