"""Fig. 5 reproduction: projected hybrid vs DP-only speedup across device
counts for Inception-V3 / GNMT / BigLSTM, from the paper's own Fig. 4 epoch
tables + Table 1 MP speedups (SE_N = 1, the paper's conservative setting).

Validates the paper's headline numbers: hybrid >= +26.5% (Inception, 256),
>= +8% (GNMT, 256), >= +22% (BigLSTM, at DP's best scale).
"""
from __future__ import annotations

from repro.core.analytical import TrainingRun, speedup_dp, speedup_hybrid
from repro.core.stateff import PAPER_MINI_BATCH, paper_epoch_table

NETWORKS = {
    "inception_v3": {"su2": 1.32, "dataset": 1_281_167},
    "gnmt": {"su2": 1.15, "dataset": 4_500_000},
    "biglstm": {"su2": 1.22, "dataset": 768_648_884 // 20},
}
DEVICE_COUNTS = [2, 4, 8, 16, 32, 64, 128, 256]


def make_run(net: str) -> TrainingRun:
    info = NETWORKS[net]
    return TrainingRun(
        name=net, t1=0.1, grad_bytes=4 * 25e6,
        mini_batch=PAPER_MINI_BATCH[net],
        epoch_model=paper_epoch_table(net),
        dataset_size=info["dataset"],
        mp_speedup={2: info["su2"]},
        se_perfect=True)


def planner_report(device_counts=(64, 256, 1024)):
    """Beyond the paper's 2-way projections: what the unified 3-way planner
    (DP x tensor-MP x pipeline-MP x micro-batches) actually picks per arch —
    tensor for the CNN, pipeline for the RNNs, mirroring §4.3/§4.4."""
    from repro.configs import get_config
    from repro.core.planner import HybridPlanner, default_epoch_model

    out = {}
    for net in NETWORKS:
        cfg = get_config(net)
        planner = HybridPlanner(cfg, epoch_model=default_epoch_model(cfg))
        for d in device_counts:
            cs = planner.choices(d)
            if not cs:
                print(f"fig5,planner,network={net},devices={d},infeasible")
                continue
            b = cs[0]
            out[(net, d)] = b
            print(f"fig5,planner,network={net},devices={d},kind={b.mp_kind},"
                  f"dp={b.n_workers},mp={b.mp},micro={b.microbatches},"
                  f"su={b.speedup:.2f}")
    return out


def run():
    claims = {}
    for net in NETWORKS:
        run_ = make_run(net)
        best_dp = 0.0
        for d in DEVICE_COUNTS:
            dp = speedup_dp(run_, d)
            hyb = speedup_hybrid(run_, d // 2, 2) if d >= 2 else dp
            best_dp = max(best_dp, dp)
            gain = hyb / dp if dp > 0 else float("inf")
            print(f"fig5,network={net},devices={d},su_dp={dp:.2f},"
                  f"su_hybrid={hyb:.2f},gain={gain:.3f}", flush=True)
        # headline claims
        if net == "inception_v3":
            g = speedup_hybrid(run_, 128, 2) / speedup_dp(run_, 256)
            claims[net] = (g, 1.265)
        elif net == "gnmt":
            g = speedup_hybrid(run_, 128, 2) / speedup_dp(run_, 256)
            claims[net] = (g, 1.08)
        else:
            g = speedup_hybrid(run_, 16, 2) / best_dp
            claims[net] = (g, 1.22)
    for net, (g, target) in claims.items():
        status = "PASS" if g >= target * 0.97 else "FAIL"
        print(f"fig5,claim_{net}_gain={g:.3f},paper_target={target},{status}")
    planner_report()
    return claims


if __name__ == "__main__":
    run()
