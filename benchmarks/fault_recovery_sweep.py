"""Fault-recovery sweep: checkpoint overhead, retry/restart recovery cost,
and end-to-end preemption + corruption-fallback lanes.

    PYTHONPATH=src python -m benchmarks.fault_recovery_sweep [--smoke]

Emits ``BENCH_fault.json`` with three sections:

- **checkpoint** — save/restore wall time and file size for the reduced
  model's full TrainState, then training wall time across a checkpoint
  cadence sweep with synchronous vs background saves: the background lane's
  overhead-per-checkpoint is the number that says whether serialization is
  off the step path.

- **recovery** — in-process supervised recovery: a straight run vs the same
  run under a seeded schedule that exhausts the step-retry budget AND
  corrupts the newest checkpoint (forcing ``restore_latest_valid``'s
  fallback to the previous one).  Reports restarts/retries, the wall-time
  multiple of the faulted run, and asserts the recovered final state is
  BIT-EQUAL to the straight run's — recovery that changes the answer is a
  failure, not a slowdown.

- **cli_lanes** — the real ``launch.train`` process boundary: ``kill@N``
  preemption (exit 17, no cleanup) followed by ``--resume``, and a
  corruption lane where the newest checkpoint is damaged before the resume
  so restore must fall back.  Both lanes assert the resumed run's final
  checkpoint is bit-identical to an uninterrupted run's, and report the
  recovery wall time (resume process, including re-jit).

All lanes run on the CPU host: the wall times calibrate *relative* overhead
(sync vs background, straight vs faulted), not accelerator step times.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time
import warnings

ARCH = "llama3_2_1b"
FULL = dict(steps=24, batch=8, seq=16, cadences=(0, 12, 6, 3),
            fail_step=14, kill_step=18, ckpt_every=5)
SMOKE = dict(steps=12, batch=4, seq=8, cadences=(0, 6, 3),
             fail_step=10, kill_step=9, ckpt_every=4)


def _leaves(fname):
    import msgpack
    payload = msgpack.unpackb(open(fname, "rb").read(), raw=False)
    return payload["leaves"], payload["step"]


def _bench_inprocess(cfgv):
    import dataclasses

    import jax
    import numpy as np

    from repro.checkpoint import (restore_checkpoint, save_checkpoint,
                                  wait_for_saves)
    from repro.configs import get_config
    from repro.data import DataPipeline, make_lm_dataset
    from repro.models import build_model
    from repro.optim import adamw, constant_lr
    from repro.train.fault import FaultInjector, parse_fault_schedule, \
        run_supervised
    from repro.train.loop import LoopConfig, train_loop
    from repro.train.steps import (eval_train_state, init_train_state,
                                   make_train_step)

    cfg = get_config(ARCH).reduced()
    api = build_model(cfg)
    opt = adamw(constant_lr(3e-3))
    data = make_lm_dataset(vocab=min(cfg.vocab_size, 64),
                           seq_len=cfgv["seq"], n_items=256)
    batch = cfgv["batch"]

    def pipe():
        return DataPipeline(lambda e: iter(list(data.epoch(e, batch))),
                            steps_per_epoch=data.steps_per_epoch(batch))

    step_fn = jax.jit(make_train_step(api, opt), donate_argnums=(0,))
    init_fn = lambda: init_train_state(api, opt, jax.random.PRNGKey(0))

    # -- raw save/restore cost ----------------------------------------------
    state = init_fn()
    jax.block_until_ready(jax.tree.leaves(state))
    with tempfile.TemporaryDirectory() as td:
        t0 = time.perf_counter()
        fname = save_checkpoint(td, state, 1)
        t_save = time.perf_counter() - t0
        size = os.path.getsize(fname)
        t0 = time.perf_counter()
        restored = restore_checkpoint(fname, eval_train_state(api, opt))
        jax.block_until_ready(jax.tree.leaves(restored))
        t_restore = time.perf_counter() - t0
        t0 = time.perf_counter()
        save_checkpoint(td, state, 2, background=True)
        t_bg_return = time.perf_counter() - t0     # time the step path sees
        wait_for_saves()
        t_bg_total = time.perf_counter() - t0
    ckpt = {"bytes": size, "save_s": t_save, "restore_s": t_restore,
            "background_return_s": t_bg_return,
            "background_total_s": t_bg_total}

    # -- cadence sweep: training wall vs ckpt_every, sync vs background ----
    # warm the jit cache first so the no-checkpoint baseline row is not the
    # one paying compile time
    train_loop(step_fn, init_fn(), pipe(),
               LoopConfig(total_steps=2, log_every=10 ** 9),
               log_fn=lambda m: None)
    cadence = []
    for every in cfgv["cadences"]:
        for background in ((False,) if every == 0 else (False, True)):
            with tempfile.TemporaryDirectory() as td:
                c = LoopConfig(total_steps=cfgv["steps"], ckpt_every=every,
                               ckpt_dir=td if every else "",
                               background_save=background,
                               final_ckpt=False, log_every=10 ** 9)
                t0 = time.perf_counter()
                s = train_loop(step_fn, init_fn(), pipe(), c,
                               log_fn=lambda m: None)
                wall = time.perf_counter() - t0
            cadence.append({"ckpt_every": every, "background": background,
                            "wall_s": wall, "checkpoints": s["checkpoints"]})
    base_wall = cadence[0]["wall_s"]
    for row in cadence:
        row["overhead_per_ckpt_s"] = (
            (row["wall_s"] - base_wall) / row["checkpoints"]
            if row["checkpoints"] else 0.0)

    # -- supervised recovery: fail past retries + corrupt newest ckpt -------
    t0 = time.perf_counter()
    straight = train_loop(step_fn, init_fn(), pipe(),
                          LoopConfig(total_steps=cfgv["steps"],
                                     log_every=10 ** 9),
                          log_fn=lambda m: None)
    wall_straight = time.perf_counter() - t0
    fs, ce = cfgv["fail_step"], cfgv["ckpt_every"]
    corrupt_at = ((fs - 1) // ce) * ce      # newest checkpoint before fail
    schedule = f"fail@{fs}x3, corrupt@{corrupt_at}:bitflip"
    inj = FaultInjector(parse_fault_schedule(schedule), log_fn=lambda m: None)
    with tempfile.TemporaryDirectory() as td:
        c = LoopConfig(total_steps=cfgv["steps"], ckpt_every=ce, ckpt_dir=td,
                       max_retries=1, retry_backoff_s=0.0, log_every=10 ** 9)
        t0 = time.perf_counter()
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")   # expected "skipping ckpt_*"
            faulted = run_supervised(inj.wrap_step(step_fn), pipe(), c,
                                     init_fn=init_fn,
                                     like=eval_train_state(api, opt),
                                     max_restarts=2, restart_backoff_s=0.0,
                                     log_fn=lambda m: None,
                                     on_checkpoint=inj.after_save)
        wall_faulted = time.perf_counter() - t0
    a = [np.asarray(x).tobytes()
         for x in jax.tree.leaves(jax.device_get(straight["state"]))]
    b = [np.asarray(x).tobytes()
         for x in jax.tree.leaves(jax.device_get(faulted["state"]))]
    recovery = {"schedule": schedule, "wall_straight_s": wall_straight,
                "wall_faulted_s": wall_faulted,
                "slowdown": wall_faulted / max(wall_straight, 1e-9),
                "restarts": faulted["restarts"],
                "retries": faulted["retries"],
                "bit_equal": a == b}
    assert recovery["bit_equal"], "recovered state != straight run"
    return ckpt, cadence, recovery


def _run_cli(args, env=None, check_rc=0):
    env = dict(os.environ, JAX_PLATFORMS="cpu",
               PYTHONPATH=os.environ.get("PYTHONPATH", "src"),
               **(env or {}))
    t0 = time.perf_counter()
    r = subprocess.run([sys.executable, "-m", "repro.launch.train"] + args,
                       capture_output=True, text=True, env=env, timeout=1800)
    wall = time.perf_counter() - t0
    if r.returncode != check_rc:
        raise RuntimeError(f"rc={r.returncode} (want {check_rc})\n"
                           f"{r.stdout}\n{r.stderr[-2000:]}")
    return wall, r.stdout


def _bench_cli_lanes(cfgv):
    from repro.checkpoint import latest_checkpoint
    from repro.train.fault import KILL_EXIT_CODE, corrupt_checkpoint

    steps, kill = cfgv["steps"], cfgv["kill_step"]
    base = ["--arch", ARCH, "--reduced", "--steps", str(steps),
            "--batch", str(cfgv["batch"]), "--seq", str(cfgv["seq"]),
            "--ckpt-every", str(cfgv["ckpt_every"])]
    lanes = {}
    with tempfile.TemporaryDirectory() as td:
        d_straight = os.path.join(td, "straight")
        wall_straight, _ = _run_cli(base + ["--ckpt-dir", d_straight])
        ref_leaves, ref_step = _leaves(latest_checkpoint(d_straight))
        lanes["straight"] = {"wall_s": wall_straight, "final_step": ref_step}

        # preemption: kill@N, then a fresh process resumes
        d = os.path.join(td, "kill")
        wall_kill, _ = _run_cli(
            base + ["--ckpt-dir", d, "--fault", f"kill@{kill}"],
            check_rc=KILL_EXIT_CODE)
        wall_resume, out = _run_cli(base + ["--ckpt-dir", d, "--resume"])
        leaves, step = _leaves(latest_checkpoint(d))
        lanes["kill_resume"] = {
            "kill_at": kill, "wall_killed_s": wall_kill,
            "wall_resume_s": wall_resume,
            "restored": "[resume] restored" in out,
            "bit_equal_final": (step == ref_step and leaves == ref_leaves)}

        # corruption: damage the newest checkpoint; resume must fall back
        d = os.path.join(td, "corrupt")
        _run_cli(base + ["--ckpt-dir", d, "--fault", f"kill@{kill}"],
                 check_rc=KILL_EXIT_CODE)
        newest = latest_checkpoint(d)
        corrupt_checkpoint(newest, "bitflip")
        wall_resume, out = _run_cli(base + ["--ckpt-dir", d, "--resume"])
        leaves, step = _leaves(latest_checkpoint(d))
        lanes["corrupt_fallback"] = {
            "corrupted": os.path.basename(newest),
            "wall_resume_s": wall_resume,
            "fell_back": os.path.basename(newest) not in out
            and "[resume] restored" in out,
            "bit_equal_final": (step == ref_step and leaves == ref_leaves)}
    for name in ("kill_resume", "corrupt_fallback"):
        assert lanes[name]["bit_equal_final"], f"{name}: final ckpt differs"
    assert lanes["corrupt_fallback"]["fell_back"]
    return lanes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_fault.json")
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for the CI smoke lane")
    args = ap.parse_args(argv)
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    cfgv = SMOKE if args.smoke else FULL

    ckpt, cadence, recovery = _bench_inprocess(cfgv)
    cli_lanes = _bench_cli_lanes(cfgv)

    rec = {"bench": "fault_recovery_sweep", "smoke": bool(args.smoke),
           "arch": ARCH, **{k: cfgv[k] for k in ("steps", "batch", "seq")},
           "checkpoint": ckpt, "cadence": cadence, "recovery": recovery,
           "cli_lanes": cli_lanes}
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"fault_sweep,done,out={args.out},"
          f"save_s={ckpt['save_s']:.3f},"
          f"bg_return_s={ckpt['background_return_s']:.3f},"
          f"recovery_bit_equal={recovery['bit_equal']},"
          f"restarts={recovery['restarts']},"
          f"kill_bit_equal={cli_lanes['kill_resume']['bit_equal_final']},"
          f"corrupt_fell_back={cli_lanes['corrupt_fallback']['fell_back']}")
    return 0


def run(out: str = "BENCH_fault.json") -> None:
    """benchmarks.run entry: subprocess so jax backend state stays clean."""
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.fault_recovery_sweep",
         "--out", out], env=env, text=True, capture_output=True, timeout=3600)
    sys.stdout.write(r.stdout)
    if r.returncode:
        sys.stdout.write(r.stderr[-2000:])
        print("fault_sweep,failed")


if __name__ == "__main__":
    sys.exit(main())
