"""Pipeline-schedule sweep: measured step time vs the analytic bubble model.

    PYTHONPATH=src python -m benchmarks.pipeline_schedule_sweep [--out ...]

Runs a real fwd+bwd training step through ``parallel.pipeline.pipeline_apply``
on a forced S-device host mesh for every (schedule, micro-batch count) point,
and emits ``BENCH_pipeline.json`` with

- per-point measured step time (min over reps) next to the schedule's
  analytic bubble fraction / activation residency / tick counts — the perf
  trajectory seed;
- a **runtime lane** per point: the same (schedule, K) executed by both
  pipeline runtimes — ``ad`` (jax.grad through ``pipeline_apply``'s forward
  scan) and ``scheduled`` (``pipeline_value_and_grad``, the hand-scheduled
  fwd+bwd WorkUnit executor) — with measured step time, the XLA-reported
  temp bytes, and the scheduled runtime's *actual* activation-store size
  (``plan_scheduled_runtime``: min(K, S) slots for 1f1b vs K for gpipe);
- a calibration fit of the analytic model ``t = c / (1 - bubble)`` against
  the ad-lane measurements (the ROADMAP item: calibrate the bubble +
  transfer model against measured ``pipeline_apply`` step times) with
  per-point residuals;
- an **equal-memory comparison**: at the activation budget GPipe needs for
  its K (residency = K micro-batches live), 1F1B fits K' >= K (residency
  min(K', S)) and interleaved fits vK' ticks of wave — so both run a larger
  feasible micro-batch count and a smaller bubble, and their measured step
  time must come in at or under GPipe's.

On the ad runtime gpipe and 1f1b share one executable forward dataflow at
equal K (AD builds the backward), so their measured times differ only at
the *feasible* K each schedule's memory model admits.  The scheduled
runtime is where the schedules actually diverge at runtime: 1f1b's store
holds min(K, S) stage inputs vs gpipe's K at identical tick counts.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

STAGES = 4
VIRTUAL = 2
LAYERS = 8
# sized so per-tick activation compute dominates the host-mesh per-tick
# collective/dispatch overhead (~10 ms/tick on a 2-core container) — small
# d keeps param-grad accumulation cheap, the large batch carries the work
D_MODEL = 256
BATCH = 8192
MICROS = (4, 8, 16)
# equal-memory budget: gpipe@K=8 keeps 8 micro-batches of activations live
EQUAL_MEM_BUDGET = 8


def _sweep_points():
    """(schedule, K, v) grid; interleaved needs S | K for the packed wave."""
    pts = [("gpipe", k, 1) for k in MICROS]
    pts += [("1f1b", k, 1) for k in MICROS]
    pts += [("interleaved", k, VIRTUAL) for k in MICROS if k % STAGES == 0]
    return pts


def _measure(reps: int, warmup: int):
    """The timed sweep — runs in a process whose jax sees STAGES devices."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.parallel.jaxcompat import make_mesh, set_mesh
    from repro.parallel.pipeline import (make_schedule, pipeline_apply,
                                         pipeline_value_and_grad,
                                         plan_scheduled_runtime,
                                         stack_to_stages)

    mesh = make_mesh((1, STAGES), ("data", "model"))
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (LAYERS, D_MODEL, D_MODEL)) * 0.02,
              "b": jnp.zeros((LAYERS, D_MODEL))}
    x = jax.random.normal(jax.random.PRNGKey(1), (BATCH, D_MODEL))

    def stage_fn(sp, x):
        y, _ = jax.lax.scan(
            lambda x, lp: (jnp.tanh(x @ lp["w"] + lp["b"]), None), x, sp)
        return y

    def _time(compiled, args):
        jax.block_until_ready(compiled(*args))
        for _ in range(warmup):
            jax.block_until_ready(compiled(*args))
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(compiled(*args))
            best = min(best, time.perf_counter() - t0)
        return best

    points = []
    for sched_kind, k, v in _sweep_points():
        sched = make_schedule(sched_kind, STAGES, k, v)
        stacked = stack_to_stages(params, STAGES, v)
        mb_bytes = (BATCH // k) * D_MODEL * 4          # one f32 stage input

        def ad_step(p, x):
            def loss(p, x):
                y = pipeline_apply(mesh, "model", stage_fn, p, x, n_micro=k,
                                   schedule=sched_kind, virtual_stages=v)
                return (y ** 2).mean()

            return jax.value_and_grad(loss)(p, x)

        inv = 1.0 / (BATCH * D_MODEL)

        def sched_step(p, x):
            def loss_fn(lp, y_m, t_m):
                return (y_m ** 2).sum() * inv

            l, (gs, _, _) = pipeline_value_and_grad(
                mesh, "model", stage_fn, p, x, loss_fn=loss_fn,
                loss_params={}, n_micro=k, schedule=sched_kind,
                virtual_stages=v)
            return l, gs

        rtp = plan_scheduled_runtime(sched)
        lanes = {}
        with set_mesh(mesh):
            for name, fn in (("ad", ad_step), ("scheduled", sched_step)):
                compiled = jax.jit(fn).lower(stacked, x).compile()
                ma = compiled.memory_analysis()
                lanes[name] = {
                    "step_time_s": _time(compiled, (stacked, x)),
                    "xla_temp_bytes": int(ma.temp_size_in_bytes),
                }
            lanes["scheduled"].update({
                "store_slots": rtp.fwd_slots,
                "store_bytes": rtp.fwd_slots * mb_bytes,
                "cotangent_store_bytes": rtp.bwd_slots * mb_bytes,
            })
            # the ad runtime stashes every micro-batch boundary across the
            # fwd->bwd transpose regardless of schedule
            lanes["ad"].update({"store_slots": k * max(v, 1),
                                "store_bytes": k * max(v, 1) * mb_bytes})
        best = lanes["ad"]["step_time_s"]
        tbl = sched.table()
        points.append({
            "schedule": sched_kind, "n_micro": k, "virtual_stages": v,
            "step_time_s": best,
            "runtimes": lanes,
            "bubble_fraction": sched.bubble_fraction(),
            "activation_residency_microbatches":
                sched.activation_residency(),
            "fwd_ticks": sched.fwd_ticks,
            "total_ticks": tbl[-1].tick + 1,
        })
        print(f"pipeline_sweep,schedule={sched_kind},micro={k},v={v},"
              f"ad_step_s={best:.5f},"
              f"scheduled_step_s={lanes['scheduled']['step_time_s']:.5f},"
              f"bubble={sched.bubble_fraction():.4f},"
              f"resid={sched.activation_residency():.1f},"
              f"store={lanes['scheduled']['store_slots']}"
              f"/{lanes['ad']['store_slots']}", flush=True)
    return points


def _calibrate(points):
    """Least-squares fit of t = c / (1 - bubble) + o * ticks.

    The first term is the analytic bubble model (c = ideal zero-bubble step
    time; total compute is constant across the sweep, the bubble stretches
    it); the second is the substrate's per-tick collective/dispatch
    overhead (ppermute rendezvous — the ROADMAP transfer-model term).
    Residuals per point show how well the closed forms explain the
    measurements."""
    import numpy as np

    A = np.array([[1.0 / (1.0 - p["bubble_fraction"]),
                   float(p["fwd_ticks"] + STAGES - 1)] for p in points])
    t = np.array([p["step_time_s"] for p in points])
    (c, o), *_ = np.linalg.lstsq(A, t, rcond=None)
    pred = A @ np.array([c, o])
    resid = {f'{p["schedule"]}@{p["n_micro"]}':
             float(p["step_time_s"] / max(pr, 1e-12) - 1.0)
             for p, pr in zip(points, pred)}
    return {"ideal_step_s": float(c),
            "per_tick_overhead_s": float(o),
            "per_point_rel_err": resid,
            "max_abs_rel_err": max(abs(r) for r in resid.values())}


def _runtime_comparison(points):
    """Scheduled-vs-ad lane summary: per-point step-time ratio plus the
    store realization that is the scheduled runtime's point — 1f1b's
    activation store strictly under gpipe's at K > S (the ad lanes tie at
    K slots for every schedule)."""
    out = {"points": {}}
    for p in points:
        ad, sc = p["runtimes"]["ad"], p["runtimes"]["scheduled"]
        out["points"][f'{p["schedule"]}@{p["n_micro"]}'] = {
            "scheduled_over_ad_time": sc["step_time_s"] / ad["step_time_s"],
            "store_slots_scheduled": sc["store_slots"],
            "store_slots_ad": ad["store_slots"],
        }
    f = {p["n_micro"]: p for p in points if p["schedule"] == "1f1b"}
    g = {p["n_micro"]: p for p in points if p["schedule"] == "gpipe"}
    out["1f1b_store_lt_gpipe_at_K_gt_S"] = {
        str(k): f[k]["runtimes"]["scheduled"]["store_slots"]
        < g[k]["runtimes"]["scheduled"]["store_slots"]
        for k in f if k in g and k > STAGES}
    return out


def _equal_memory(points):
    """Best measured step time per schedule among points whose activation
    residency fits the EQUAL_MEM_BUDGET micro-batch budget."""
    best = {}
    for p in points:
        if p["activation_residency_microbatches"] > EQUAL_MEM_BUDGET:
            continue
        cur = best.get(p["schedule"])
        if cur is None or p["step_time_s"] < cur["step_time_s"]:
            best[p["schedule"]] = p
    out = {"budget_microbatches": EQUAL_MEM_BUDGET,
           "best_feasible": {s: {"n_micro": p["n_micro"],
                                 "step_time_s": p["step_time_s"],
                                 "bubble_fraction": p["bubble_fraction"]}
                             for s, p in best.items()}}
    g = best.get("gpipe")
    for s in ("1f1b", "interleaved"):
        if g and s in best:
            out[f"{s}_le_gpipe"] = bool(
                best[s]["step_time_s"] <= g["step_time_s"])
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_pipeline.json")
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--warmup", type=int, default=1)
    args = ap.parse_args(argv)

    # the forced host-device count must land before jax initializes —
    # append to any pre-existing XLA_FLAGS rather than skipping it
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={STAGES}"
            .strip())
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    points = _measure(args.reps, args.warmup)
    rec = {
        "bench": "pipeline_schedule_sweep",
        "stages": STAGES, "layers": LAYERS, "d_model": D_MODEL,
        "batch": BATCH,
        "points": points,
        "calibration": _calibrate(points),
        "equal_memory": _equal_memory(points),
        "runtime_comparison": _runtime_comparison(points),
    }
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=1)
    em = rec["equal_memory"]
    print(f"pipeline_sweep,done,out={args.out},"
          f"1f1b_le_gpipe={em.get('1f1b_le_gpipe')},"
          f"interleaved_le_gpipe={em.get('interleaved_le_gpipe')}")
    return 0


def run(out: str = "BENCH_pipeline.json") -> None:
    """benchmarks.run entry: re-exec in a subprocess so the forced host
    device count does not fight the already-initialized jax here."""
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={STAGES}",
               JAX_PLATFORMS="cpu")
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.pipeline_schedule_sweep",
         "--out", out], env=env, text=True, capture_output=True, timeout=1800)
    sys.stdout.write(r.stdout)
    if r.returncode:
        sys.stdout.write(r.stderr[-2000:])
        print("pipeline_sweep,failed")


if __name__ == "__main__":
    sys.exit(main())
