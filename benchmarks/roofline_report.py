"""Roofline table: aggregates results/dryrun/*.json into the §Roofline report
(per arch x shape x mesh: the three terms, bottleneck, useful-flops ratio,
fit).  Also emits the EXPERIMENTS.md section when run with --write-md.
"""
from __future__ import annotations

import glob
import json
import os
import sys

COLS = ("arch", "shape", "mesh", "plan")


def load_records(path="results/dryrun"):
    recs = []
    for f in sorted(glob.glob(os.path.join(path, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def fmt_row(r):
    ro = r["roofline"]
    mem_gb = r["memory"]["peak_bytes"] / 2 ** 30
    return (f"{r['arch']},{r['shape']},{r['mesh']},{r['plan']},"
            f"{ro['t_compute']:.3e},{ro['t_memory']:.3e},"
            f"{ro['t_collective']:.3e},{ro['bottleneck']},"
            f"{ro['useful_flops_ratio']:.3f},{ro['mfu']:.3f},"
            f"{mem_gb:.1f},{'fit' if r['fits'] else 'OVER'}")


def run(path="results/dryrun"):
    recs = load_records(path)
    print("roofline,arch,shape,mesh,plan,t_compute,t_memory,t_collective,"
          "bottleneck,useful_ratio,mfu,peak_GiB,fits")
    for r in recs:
        print("roofline," + fmt_row(r))
    n_single = sum(1 for r in recs if r["mesh"] == "16x16")
    n_multi = sum(1 for r in recs if r["mesh"] == "2x16x16")
    print(f"roofline,summary,single_pod_combos={n_single},"
          f"multi_pod_combos={n_multi}")
    return recs


def to_markdown(recs):
    lines = ["| arch | shape | mesh | plan | t_comp (s) | t_mem (s) | "
             "t_coll (s) | bottleneck | useful | MFU bound | peak GiB | fit |",
             "|---|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        ro = r["roofline"]
        mem_gb = r["memory"]["peak_bytes"] / 2 ** 30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['plan']} | "
            f"{ro['t_compute']:.2e} | {ro['t_memory']:.2e} | "
            f"{ro['t_collective']:.2e} | {ro['bottleneck']} | "
            f"{ro['useful_flops_ratio']:.2f} | {ro['mfu']:.3f} | "
            f"{mem_gb:.1f} | {'y' if r['fits'] else 'OVER'} |")
    return "\n".join(lines)


if __name__ == "__main__":
    recs = run()
    if "--write-md" in sys.argv:
        print(to_markdown(recs))
