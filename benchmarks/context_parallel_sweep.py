"""Context-parallel sweep: ring attention vs the all-gather-then-attend
baseline on a forced host ring.

    PYTHONPATH=src python -m benchmarks.context_parallel_sweep [--smoke]

Emits ``BENCH_cp.json`` with two sections:

- **attention** — batched GQA attention with the sequence sharded over a
  ``MESH_M``-way ring, fwd+bwd, under (a) ``gathered_attention`` (GSPMD's
  lowering: all-gather the full K/V on every device, attend locally) and
  (b) ``ring_attention`` (``parallel.context``: ppermute the KV shard
  around the ring with online-softmax folding; causal runs skip whole
  remote blocks by ring distance).  Per lane: measured step time,
  collective op counts and per-chip wire bytes parsed from the compiled
  HLO.  The ring lane's wire bytes are ASSERTED against the analytic ring
  model (3 rotations per step — fwd KV, bwd KV, bwd dK/dV accumulators —
  of one K+V sequence shard per hop), and its HLO must contain no
  monolithic all-gather / all-reduce carrying a KV-sized payload: every
  real collective on the hot path is a shard-sized collective-permute.
  The gathered lane is the foil — its HLO carries the full-KV all-gather
  the ring exists to avoid.

- **planner** — the ``HybridPlanner`` view of the new context axis for the
  dense-decoder arch: per-ring-size ``cp_step_speedup`` and the arg-max
  kind at 64/256 devices (the BENCH-visible form of the pinned goldens in
  ``tests/test_planner_golden.py``).

The step-time ratio is host-mesh CPU timing (no async collectives, no real
ICI): treat ``ring_le_gathered`` as a sanity direction, and re-measure on
real hardware before quoting speedups — the same caveat as
BENCH_collectives.json's overlap constant.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

MESH_M = 4          # ring size (= forced host devices)
# full-mode sizing: the causal block-skip's compute saving must dominate the
# host-mesh per-collective dispatch overhead for the ring to be measurable
FULL = dict(batch=2, seq=1024, n_heads=4, n_kv_heads=2, head_dim=64,
            reps=5, warmup=1)
SMOKE = dict(batch=1, seq=256, n_heads=4, n_kv_heads=2, head_dim=32,
             reps=2, warmup=1)


def _measure(cfgv, check_time: bool):
    import functools
    import time

    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.core.roofline import (_GROUPS_IOTA_RE, _GROUPS_LIST_RE,
                                     _tensor_bytes, parse_collectives)
    from repro.parallel.context import gathered_attention, ring_attention
    from repro.parallel.jaxcompat import make_mesh, set_mesh, shard_map

    m = MESH_M
    b, t = cfgv["batch"], cfgv["seq"]
    hq, hkv, hd = cfgv["n_heads"], cfgv["n_kv_heads"], cfgv["head_dim"]
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, t, hq, hd))
    k = jax.random.normal(kk, (b, t, hkv, hd))
    v = jax.random.normal(kv, (b, t, hkv, hd))
    mesh = make_mesh((1, m), ("data", "model"))
    spec = P(None, "model", None, None)

    def _time(compiled, args):
        jax.block_until_ready(compiled(*args))
        for _ in range(cfgv["warmup"]):
            jax.block_until_ready(compiled(*args))
        best = float("inf")
        for _ in range(cfgv["reps"]):
            t0 = time.perf_counter()
            jax.block_until_ready(compiled(*args))
            best = min(best, time.perf_counter() - t0)
        return best

    def lane_loss(attn_fn):
        def loss(q, k, v):
            fn = functools.partial(attn_fn, axis="model", axis_size=m,
                                   causal=True)
            o = shard_map(fn, mesh=mesh, in_specs=(spec,) * 3,
                          out_specs=spec)(q, k, v)
            return (o.astype(jnp.float32) ** 2).sum()
        return loss

    # one K+V sequence shard per hop, f32; 3 rotations per fwd+bwd step
    # (fwd KV, bwd KV replay, bwd dK/dV accumulators riding home) — the
    # backward's accumulator ring takes m hops (the last one carries the
    # shard back to its owner), the KV rings m-1
    pair_bytes = 2 * b * (t // m) * hkv * hd * 4
    wire_lo = 3 * (m - 1) * pair_bytes
    wire_hi = (m - 1) * 2 * pair_bytes + 2 * m * pair_bytes

    def group_size(ln):
        g = _GROUPS_IOTA_RE.search(ln)
        if g:
            return int(g.group(2))
        g = _GROUPS_LIST_RE.search(ln)
        if g:
            return len([s for s in g.group(1).split(",") if s.strip()])
        return m

    points = {}
    with set_mesh(mesh):
        for lane, attn in (("gathered", gathered_attention),
                           ("ring", ring_attention)):
            fn = jax.jit(jax.value_and_grad(lane_loss(attn),
                                            argnums=(0, 1, 2)))
            compiled = fn.lower(q, k, v).compile()
            stats = parse_collectives(compiled.as_text(), default_group=m)
            pt = {"lane": lane, "step_time_s": _time(compiled, (q, k, v)),
                  "ops": stats.ops, "wire_bytes": stats.wire_bytes}
            if lane == "ring":
                pt["expected_wire_bytes"] = [wire_lo, wire_hi]
                assert 0.75 * wire_lo <= stats.wire_bytes \
                    <= 1.25 * wire_hi + 1024, \
                    (stats.wire_bytes, wire_lo, wire_hi, stats.ops)
                assert stats.ops.get("collective-permute", 0) > 0, stats.ops
                # no monolithic KV gather smuggled back in: any all-gather /
                # all-reduce over a real (>1) group must be smaller than one
                # KV shard (scalar loss psums are fine)
                mono = [ln for ln in stats.lines
                        if ("all-gather" in ln or "all-reduce" in ln)
                        and group_size(ln) > 1
                        and _tensor_bytes(ln) >= pair_bytes // 2]
                assert not mono, mono
            else:
                # the foil carries the full-KV all-gather by construction
                assert stats.ops.get("all-gather", 0) > 0, stats.ops
            points[lane] = pt
            print(f"cp_sweep,lane={lane},step_s={pt['step_time_s']:.4f},"
                  f"wire={pt['wire_bytes']:.0f},ops={stats.ops}", flush=True)

    ratio = points["ring"]["step_time_s"] / points["gathered"]["step_time_s"]
    if check_time:
        assert ratio <= 1.0, \
            f"ring slower than the all-gather baseline: ratio={ratio:.3f}"
    return {"mesh_m": m, "points": list(points.values()),
            "gathered_step_s": points["gathered"]["step_time_s"],
            "ring_step_s": points["ring"]["step_time_s"],
            "ring_over_gathered": ratio,
            "ring_le_gathered": bool(ratio <= 1.0)}


def _planner_view():
    from repro.configs import get_config
    from repro.core.planner import HybridPlanner, default_epoch_model
    cfg = get_config("llama3_2_1b")
    pl = HybridPlanner(cfg, epoch_model=default_epoch_model(cfg))
    out = {"cp_step_speedup": {str(m): su
                               for m, su in sorted(pl.run.cp_speedup.items())},
           "tensor_step_speedup": {str(m): su
                                   for m, su in sorted(pl.run.mp_speedup.items())}}
    for d in (64, 256):
        b = pl.best(d)
        out[f"best_{d}"] = {"kind": b.mp_kind, "dp": b.dp, "mp": b.mp,
                            "speedup": b.speedup}
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_cp.json")
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes / few reps for the CI smoke lane "
                         "(records but does not assert the timing ratio)")
    args = ap.parse_args(argv)

    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={MESH_M}"
            .strip())
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    cfgv = SMOKE if args.smoke else FULL
    attention = _measure(cfgv, check_time=not args.smoke)
    rec = {
        "bench": "context_parallel_sweep",
        "smoke": bool(args.smoke),
        **{k: cfgv[k] for k in ("batch", "seq", "n_heads", "n_kv_heads",
                                "head_dim")},
        "attention": attention,
        "planner": _planner_view(),
    }
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"cp_sweep,done,out={args.out},"
          f"ring_le_gathered={attention['ring_le_gathered']},"
          f"ring_over_gathered={attention['ring_over_gathered']:.3f}")
    return 0


def run(out: str = "BENCH_cp.json") -> None:
    """benchmarks.run entry: re-exec in a subprocess so the forced host
    device count does not fight the already-initialized jax here."""
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={MESH_M}",
               JAX_PLATFORMS="cpu")
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.context_parallel_sweep",
         "--out", out], env=env, text=True, capture_output=True, timeout=1800)
    sys.stdout.write(r.stdout)
    if r.returncode:
        sys.stdout.write(r.stderr[-2000:])
        print("cp_sweep,failed")


if __name__ == "__main__":
    sys.exit(main())
