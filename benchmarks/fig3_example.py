"""Fig. 3 reproduction: the paper's illustrative scenario — MP gives 45%/65%
per-step speedup at 2/4 GPUs, DP scales well to 32 devices then slows; the
figure's qualitative claims are asserted:

  (a) 32-way-DP x 2-way-MP beats 64-way DP;
  (b) 16-way-DP x 4-way-MP beats 128-way DP at 64+ devices;
  (c) ...but the 2-way hybrid beats the 4-way hybrid at equal device counts
      (SU^4 doesn't pay for halving N twice).
"""
from __future__ import annotations

from repro.core.analytical import TrainingRun, speedup_dp, speedup_hybrid
from repro.core.stateff import EpochModel


def make_run() -> TrainingRun:
    # DP "scales well up to 32 devices, then slows": critical batch at 32
    # workers' global batch
    return TrainingRun(
        name="fig3", t1=0.1, grad_bytes=4 * 25e6, mini_batch=64,
        epoch_model=EpochModel(e_inf=4.0, b_crit=32 * 64, alpha=1.6),
        dataset_size=1_000_000,
        mp_speedup={2: 1.45, 4: 1.65},
        se_perfect=True)


def run():
    r = make_run()
    print("fig3,devices,su_dp,su_hybrid_m2,su_hybrid_m4")
    for d in (8, 16, 32, 64, 128, 256):
        dp = speedup_dp(r, d)
        h2 = speedup_hybrid(r, d // 2, 2)
        h4 = speedup_hybrid(r, d // 4, 4) if d >= 4 else 0
        print(f"fig3,{d},{dp:.2f},{h2:.2f},{h4:.2f}")
    a = speedup_hybrid(r, 32, 2) > speedup_dp(r, 64)
    b = speedup_hybrid(r, 16, 4) > speedup_dp(r, 128)
    c = speedup_hybrid(r, 32, 2) > speedup_hybrid(r, 16, 4)
    print(f"fig3,claim_hybrid2_beats_dp64={'PASS' if a else 'FAIL'}")
    print(f"fig3,claim_hybrid4_beats_dp128={'PASS' if b else 'FAIL'}")
    print(f"fig3,claim_m2_beats_m4_at_64={'PASS' if c else 'FAIL'}")
    return a and b and c


if __name__ == "__main__":
    run()
