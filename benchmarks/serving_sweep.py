"""Serving sweep: continuous-batching engine under a Poisson request trace.

    PYTHONPATH=src python -m benchmarks.serving_sweep [--smoke]

Emits ``BENCH_serving.json`` with two sections:

- **poisson_trace** — the ``serve.continuous.ContinuousEngine`` (slotted KV
  cache, chunked prefill interleaved with decode ticks) driven by a seeded
  Poisson arrival process over a reduced llama: requests are submitted as
  their arrival times pass, the scheduler ``step()`` loop runs open-loop,
  and each request's submit-to-finish latency is recorded.  Reported:
  sustained generated tokens/s over the busy interval, request-latency
  p50/p99, mean queue wait (arrival -> first prefill opportunity proxy),
  and slot occupancy.  CPU wall-clock numbers calibrate the *scheduler*
  (admission, chunking, eviction), not the accelerator — the decode-step
  latency model for real hardware is ``core.planner.decode_step_time``.

- **planner_slo** — ``HybridPlanner.best_inference``: the latency-SLO-
  constrained (DP replicas x TP, slots) search over a device budget on the
  modeled hardware, for a few SLO points (the serving analogue of the
  training crossover section in BENCH_collectives.json).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

FULL = dict(n_requests=24, n_slots=4, max_new=16, prompt_lo=8, prompt_hi=32,
            prefill_chunk=8, mean_interarrival_s=0.05)
SMOKE = dict(n_requests=6, n_slots=2, max_new=8, prompt_lo=4, prompt_hi=12,
             prefill_chunk=4, mean_interarrival_s=0.02)


def _percentile(xs, q):
    xs = sorted(xs)
    if not xs:
        return None
    i = min(len(xs) - 1, max(0, int(round(q / 100.0 * (len(xs) - 1)))))
    return xs[i]


def _poisson_trace(cfgv, seed=0):
    import numpy as np

    import jax
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve.continuous import ContinuousEngine, Request

    rng = np.random.default_rng(seed)
    n = cfgv["n_requests"]
    arrivals = np.cumsum(rng.exponential(cfgv["mean_interarrival_s"], n))
    prompts = [rng.integers(1, 900, size=int(rng.integers(
        cfgv["prompt_lo"], cfgv["prompt_hi"] + 1))).tolist()
        for _ in range(n)]

    cfg = get_config("llama3_2_1b").reduced()
    api = build_model(cfg, remat=False)
    params = api.init(jax.random.PRNGKey(0))
    capacity = cfgv["prompt_hi"] + cfgv["max_new"] + 8
    engine = ContinuousEngine(api, params, n_slots=cfgv["n_slots"],
                              capacity=capacity,
                              prefill_chunk=cfgv["prefill_chunk"])
    # warm the jitted tick/chunk paths outside the measured interval
    warm = ContinuousEngine(api, params, n_slots=cfgv["n_slots"],
                            capacity=capacity,
                            prefill_chunk=cfgv["prefill_chunk"])
    warm.run([Request(rid=0, tokens=prompts[0], max_new_tokens=2)])

    submit_t, finish_t = {}, {}
    occupancy = []
    t0 = time.perf_counter()
    nxt = 0
    n_done = 0
    while n_done < n:
        now = time.perf_counter() - t0
        while nxt < n and arrivals[nxt] <= now:
            engine.submit(Request(rid=nxt, tokens=prompts[nxt],
                                  max_new_tokens=cfgv["max_new"]))
            submit_t[nxt] = now
            nxt += 1
        if not engine.active and not engine.queue:
            if nxt < n:           # idle: fast-forward to the next arrival
                time.sleep(max(0.0, arrivals[nxt] - (time.perf_counter() - t0)))
            continue
        engine.step()
        occupancy.append(len(engine.active))
        now = time.perf_counter() - t0
        for r in engine.results[n_done:]:
            finish_t[r.rid] = now
            n_done += 1
    results = sorted(engine.results, key=lambda r: r.rid)
    lat = [finish_t[r.rid] - submit_t[r.rid] for r in results]
    gen_tokens = sum(len(r.tokens) for r in results)
    busy = max(finish_t.values()) - min(submit_t.values())
    rec = {
        "arch": cfg.name, "n_requests": n, "n_slots": cfgv["n_slots"],
        "max_new": cfgv["max_new"], "prefill_chunk": cfgv["prefill_chunk"],
        "mean_interarrival_s": cfgv["mean_interarrival_s"],
        "generated_tokens": gen_tokens,
        "tokens_per_s": gen_tokens / max(busy, 1e-9),
        "latency_p50_s": _percentile(lat, 50),
        "latency_p99_s": _percentile(lat, 99),
        "latency_mean_s": sum(lat) / len(lat),
        "mean_slot_occupancy": sum(occupancy) / max(len(occupancy), 1),
        "steps": len(occupancy),
    }
    print(f"serving_sweep,trace,tok_s={rec['tokens_per_s']:.1f},"
          f"p50_s={rec['latency_p50_s']:.3f},p99_s={rec['latency_p99_s']:.3f},"
          f"occupancy={rec['mean_slot_occupancy']:.2f}", flush=True)
    return rec


def _planner_slo():
    from repro.configs import get_config
    from repro.core.planner import HybridPlanner, default_epoch_model

    cfg = get_config("llama3_2_1b")
    planner = HybridPlanner(cfg, epoch_model=default_epoch_model(cfg),
                            comm_runtime="overlapped")
    out = {}
    for devices, slo_ms in ((16, 20.0), (16, 5.0), (64, 10.0)):
        c = planner.best_inference(devices, slo_ms=slo_ms, context=4096)
        out[f"dev{devices}_slo{slo_ms:g}ms"] = {
            "replicas": c.replicas, "tp": c.tp, "slots": c.slots,
            "step_latency_ms": c.step_latency * 1e3,
            "tokens_per_s": c.tokens_per_s,
            "comm_runtime": c.plan.comm_runtime,
        }
        print(f"serving_sweep,planner,dev={devices},slo_ms={slo_ms:g},"
              f"tp={c.tp},replicas={c.replicas},slots={c.slots},"
              f"tok_s={c.tokens_per_s:.0f}", flush=True)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--smoke", action="store_true",
                    help="small trace / few requests for the CI smoke lane")
    args = ap.parse_args(argv)
    os.environ.setdefault("JAX_PLATFORMS", "cpu")

    cfgv = SMOKE if args.smoke else FULL
    rec = {
        "bench": "serving_sweep",
        "smoke": bool(args.smoke),
        "poisson_trace": _poisson_trace(cfgv),
        "planner_slo": _planner_slo(),
    }
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"serving_sweep,done,out={args.out},"
          f"tok_s={rec['poisson_trace']['tokens_per_s']:.1f}")
    return 0


def run(out: str = "BENCH_serving.json") -> None:
    """benchmarks.run entry."""
    main(["--out", out])


if __name__ == "__main__":
    sys.exit(main())
