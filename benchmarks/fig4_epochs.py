"""Fig. 4 reproduction: epochs-to-converge vs global batch size, measured by
REAL training runs on CPU (small transformer, Markov-chain LM task), using the
paper's §4.2 delayed-gradient emulation for batch sizes beyond the physical
device count.

Emits (global_batch, epochs) points + the fitted E(B) model, and checks the
paper's qualitative claim: epochs inflate super-linearly past a critical
batch.
"""
from __future__ import annotations

import dataclasses
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.stateff import fit_epoch_model
from repro.data import make_lm_dataset
from repro.models import build_model
from repro.optim import adamw, linear_scaled_lr
from repro.parallel.plan import ParallelPlan
from repro.train.steps import init_train_state, make_train_step


def epochs_to_converge(global_batch: int, *, base_batch: int = 16,
                       max_epochs: int = 30, seed: int = 0,
                       target_margin: float = 0.35):
    """Real convergence run at a given global batch (micro-batch fixed at
    base_batch; larger batches via gradient accumulation = paper §4.2)."""
    cfg = dataclasses.replace(get_config("smollm_360m").reduced(),
                              n_layers=2, d_model=128, d_ff=256,
                              n_heads=4, n_kv_heads=2, head_dim=32,
                              vocab_size=64)
    api = build_model(cfg)
    data = make_lm_dataset(vocab=64, seq_len=32, n_items=2048, seed=seed)
    target = data.entropy + target_margin
    accum = max(1, global_batch // base_batch)
    # linear LR scaling rule (Goyal et al.), as the paper uses for Inception
    opt = adamw(linear_scaled_lr(1e-3, base_batch, global_batch,
                                 warmup_steps=40))
    plan = ParallelPlan(microbatches=accum)
    step = jax.jit(make_train_step(api, opt, plan=plan), donate_argnums=(0,))
    state = init_train_state(api, opt, jax.random.PRNGKey(seed))

    for epoch in range(max_epochs):
        losses = []
        for batch in data.epoch(epoch, global_batch):
            b = {k: jnp.asarray(v) for k, v in batch.items()}
            state, m = step(state, b)
            losses.append(float(m["loss"]))
        tail = float(np.mean(losses[-max(1, len(losses) // 3):]))
        if tail <= target:
            return epoch + 1, tail, target
    return float(max_epochs), tail, target


def run(quick: bool = True):
    batches = [32, 64, 128, 256] if quick else [32, 64, 128, 256, 512, 1024]
    rows = []
    for gb in batches:
        t0 = time.time()
        e, final, target = epochs_to_converge(gb)
        rows.append((gb, e))
        print(f"fig4,global_batch={gb},epochs={e},final_loss={final:.4f},"
              f"target={target:.4f},wall_s={time.time()-t0:.1f}", flush=True)
    pts = {gb: float(e) for gb, e in rows}
    fit = fit_epoch_model(pts)
    print(f"fig4,fit_e_inf={fit.e_inf:.3f},fit_b_crit={fit.b_crit:.1f},"
          f"fit_alpha={fit.alpha}")
    # qualitative claim: largest batch needs more epochs than smallest
    inflated = rows[-1][1] >= rows[0][1]
    print(f"fig4,claim_epoch_inflation={'PASS' if inflated else 'FAIL'}")
    return rows


if __name__ == "__main__":
    run(quick="--full" not in sys.argv)
