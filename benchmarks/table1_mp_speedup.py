"""Table 1 reproduction: 2-way MP per-step speedup per network.

- Inception-V3: DLPlacer ILP placement on the analytic block DFG (the paper's
  §6 case study; paper: 1.32x with 2 GPUs).
- GNMT / BigLSTM: pipeline parallelism (paper: 1.15x / 1.22x) — modeled with
  the GPipe bubble + inter-stage activation transfer on the measured DFG
  costs.

Also reports tensor-MP SU^M for the assigned TPU archs (the planner's Table-1
analogue on the ICI torus).
"""
from __future__ import annotations

import time

from repro.configs import ARCH_IDS, get_config
from repro.core.dlplacer import (DFG, HardwareGraph, simulated_silicon,
                                 solve_placement)
from repro.core.comm import HardwareModel
from repro.core.planner import mp_step_speedup
from repro.models.inception import inception_dfg
from repro.parallel.pipeline import pipeline_step_speedup

PAPER_TABLE1 = {"inception_v3": 1.32, "gnmt": 1.15, "biglstm": 1.22}


def inception_mp_speedup(n_devices: int = 2, budget_s: float = 30.0):
    nodes, edges = inception_dfg(batch=32)
    dfg = DFG.from_analytic(nodes, edges)
    hw = HardwareGraph(n_devices=n_devices)
    res = solve_placement(dfg, hw, time_budget_s=budget_s)
    return res


def pipeline_mp_speedup(network: str, m: int = 2) -> float:
    """GNMT/BigLSTM pipeline SU^M from first principles: GPipe bubble +
    stage imbalance + fused-RNN kernel launch overheads + inter-stage
    activation transfer.  The paper (§4.4) attributes its modest 1.15x/1.22x
    to exactly 'kernel overheads and pipeline imbalance'."""
    launch = 30e-6            # per fused-RNN kernel launch (CuDNN-class)
    hw = HardwareGraph(n_devices=m)
    if network == "gnmt":
        # 4 enc + 4 dec LSTM layers of 1024 + attention + softmax; the
        # decoder stage carries attention+softmax => ~58% of the work
        flops = 2 * 8 * 8 * 1024 * 1024 * 50 * 128
        act = 128 * 50 * 1024 * 4
        heavy_frac = 0.58
        kernels_per_stage = 50 * 4        # seq steps x layers (fused per layer)
    else:  # biglstm: 2 LSTM layers hidden 8192 (proj 1024) + big softmax
        flops = 2 * 2 * 4 * (1024 * 8192 + 1024 * 8192) * 20 * 128
        act = 128 * 20 * 1024 * 4
        heavy_frac = 0.60                  # softmax-projection stage heavier
        kernels_per_stage = 20 * 2
    n_micro = 4
    t_total = flops / hw.flops_per_s
    t_heavy = t_total * heavy_frac / 1.0   # heaviest stage per step
    t_micro = t_heavy / n_micro
    t_comm = act / n_micro / hw.bw
    t_launch = kernels_per_stage / n_micro * launch
    ticks = n_micro + m - 1
    t_pipe = ticks * (t_micro + t_launch) + t_comm * ticks
    t_single = t_total + kernels_per_stage * 2 * launch / 1.0
    return t_single / t_pipe


def run():
    rows = {}
    t0 = time.time()
    res = inception_mp_speedup(2)
    su_inc = res.speedup_vs_single
    rows["inception_v3"] = su_inc
    print(f"table1,network=inception_v3,method=dlplacer,su2={su_inc:.3f},"
          f"paper=1.32,optimal={res.optimal},solve_s={res.solve_s:.1f}",
          flush=True)
    for net in ("gnmt", "biglstm"):
        su = pipeline_mp_speedup(net)
        rows[net] = su
        print(f"table1,network={net},method=pipeline,su2={su:.3f},"
              f"paper={PAPER_TABLE1[net]}")
    for net, su in rows.items():
        ok = abs(su - PAPER_TABLE1[net]) / PAPER_TABLE1[net] < 0.25
        print(f"table1,claim_{net}_within_25pct={'PASS' if ok else 'FAIL'}")
    # tensor-MP SU^M for the assigned archs (TPU adaptation), and what kind
    # of MP the unified planner would pick for each at the pod scale
    from repro.core.planner import (HybridPlanner, default_epoch_model,
                                    pipeline_step_speedup_model)
    hw = HardwareModel()
    for arch in ARCH_IDS + list(PAPER_TABLE1):
        cfg = get_config(arch)
        su2 = mp_step_speedup(cfg, 2, hw)
        su16 = mp_step_speedup(cfg, 16, hw)
        pipe2 = pipeline_step_speedup_model(cfg, 2, 8, hw, mini_batch=16,
                                            seq_len=4096) \
            if cfg.n_layers % 2 == 0 else float("nan")
        planner = HybridPlanner(cfg, epoch_model=default_epoch_model(cfg))
        cs = planner.choices(256)
        kind = cs[0].mp_kind if cs else "infeasible"
        print(f"table1,arch={arch},tensor_mp_su2={su2:.3f},su16={su16:.3f},"
              f"pipe_mp_su2_k8={pipe2:.3f},planner_kind_at_256={kind}")
    return rows


if __name__ == "__main__":
    run()
