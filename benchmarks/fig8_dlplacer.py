"""Fig. 8 reproduction: DLPlacer's predicted per-step speedup vs the
simulated-silicon measurement for Inception-V3 at 2/3/4 devices.

The paper reports: predicted within 6% of silicon; 2-GPU placement ~matches
the 3/4-GPU optimum (limited DFG parallelism).  Here 'silicon' is the
simulated executor with framework overheads (kernel-launch cost +
unoverlapped transfers) — see core/dlplacer.py.
"""
from __future__ import annotations

from repro.core.dlplacer import (DFG, HardwareGraph, simulated_silicon,
                                 solve_placement)
from repro.models.inception import inception_dfg


def run():
    nodes, edges = inception_dfg(batch=32)
    dfg = DFG.from_analytic(nodes, edges)
    results = {}
    for n_dev in (2, 3, 4):
        hw = HardwareGraph(n_devices=n_dev)
        res = solve_placement(dfg, hw, time_budget_s=45)
        predicted = res.speedup_vs_single
        sil_time = simulated_silicon(dfg, hw, res.placement)
        sil_single = res.single_device_time + 30e-6 * len(dfg.nodes)
        silicon = sil_single / sil_time
        gap = abs(predicted - silicon) / silicon
        results[n_dev] = (predicted, silicon, gap)
        print(f"fig8,devices={n_dev},predicted_su={predicted:.3f},"
              f"silicon_su={silicon:.3f},gap={gap*100:.1f}%,"
              f"optimal={res.optimal}", flush=True)
    ok_gap = all(g < 0.10 for _, _, g in results.values())
    print(f"fig8,claim_prediction_within_10pct={'PASS' if ok_gap else 'FAIL'}")
    # paper: 2-GPU placement close to 4-GPU optimum
    su2, su4 = results[2][0], results[4][0]
    close = su2 >= 0.9 * su4
    print(f"fig8,claim_2gpu_close_to_4gpu={'PASS' if close else 'FAIL'},"
          f"su2={su2:.3f},su4={su4:.3f}")
    return results


if __name__ == "__main__":
    run()
