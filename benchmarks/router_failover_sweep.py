"""Router failover sweep: multi-replica serving goodput under injected faults.

    PYTHONPATH=src python -m benchmarks.router_failover_sweep [--smoke]

Emits ``BENCH_router.json``: the same closed-loop request batch is pushed
through ``serve.router.ReplicaRouter`` under a grid of fault schedules —
no fault (baseline), ``kill@N:0``, ``stall@N:0:SECS`` (past the watchdog),
``nanlogits@N:0`` — and each scenario reports

- **goodput** — completed generated tokens/s over the run's wall clock
  (shed / timed-out requests contribute nothing, so dropped work shows up
  as a goodput loss, not just a counter),
- request-latency p50/p99 (submit -> result),
- exact accounting: completed / shed / timed_out / failovers, plus the
  verified invariant that every submitted rid got exactly one result,
- ``goodput_vs_baseline`` — the bounded-degradation ratio the acceptance
  criteria pin (losing 1 of R replicas should cost roughly that fraction
  of throughput, not collapse it),

and a **load-shed** scenario: more requests than the bounded queues admit,
with deadlines tight enough that the projected-wait check fires — showing
shed requests rejected at the door while admitted ones still finish.

Wall-clock numbers calibrate the *router* (dispatch, health checks,
failover replay) on CPU; modeled accelerator decode latency lives in
``core.planner.decode_step_time``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# prompt_len is FIXED per run: every distinct prompt length retraces the
# jitted prefill (seconds of XLA compile on CPU), which would both swamp
# the scheduler wall-clock being measured and trip the health watchdog on
# retraces rather than injected stalls
FULL = dict(n_requests=16, replicas=2, n_slots=2, max_new=16,
            prompt_len=10, fault_tick=6, stall_s=1.0, watchdog_s=0.5)
SMOKE = dict(n_requests=6, replicas=2, n_slots=2, max_new=6,
             prompt_len=6, fault_tick=4, stall_s=1.0, watchdog_s=0.5)


def _percentile(xs, q):
    xs = sorted(xs)
    if not xs:
        return None
    i = min(len(xs) - 1, max(0, int(round(q / 100.0 * (len(xs) - 1)))))
    return xs[i]


def _build(cfgv):
    import numpy as np

    import jax
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve.continuous import Request

    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, 900, size=cfgv["prompt_len"]).tolist()
               for _ in range(cfgv["n_requests"])]
    cfg = get_config("llama3_2_1b").reduced()
    api = build_model(cfg, remat=False)
    params = api.init(jax.random.PRNGKey(0))
    reqs = lambda **kw: [Request(rid=i, tokens=p,
                                 max_new_tokens=cfgv["max_new"], **kw)
                         for i, p in enumerate(prompts)]
    return api, params, cfg, reqs


def _scenario(api, params, cfgv, reqs, name, fault_spec, **router_kw):
    from repro.serve.router import ReplicaRouter
    from repro.train.fault import parse_fault_schedule

    router = ReplicaRouter(
        api, params, replicas=cfgv["replicas"], n_slots=cfgv["n_slots"],
        capacity=cfgv["prompt_len"] + cfgv["max_new"] + 8,
        faults=parse_fault_schedule(fault_spec) if fault_spec else (),
        watchdog_timeout_s=cfgv["watchdog_s"], retry_backoff_s=0.01,
        **router_kw)
    submit_t, finish_t = {}, {}
    t0 = time.perf_counter()
    requests = reqs()
    for r in requests:
        submit_t[r.rid] = time.perf_counter() - t0
        router.submit(r)
    seen = {res.rid for res in router.results}     # shed at the door
    for rid in seen:
        finish_t[rid] = time.perf_counter() - t0
    while router.step():
        now = time.perf_counter() - t0
        for res in router.results:
            if res.rid not in seen:
                seen.add(res.rid)
                finish_t[res.rid] = now
    wall = time.perf_counter() - t0
    router.close()
    results = sorted(router.results, key=lambda r: r.rid)
    rids_ok = [r.rid for r in results] == sorted(r.rid for r in requests)
    done = [r for r in results if r.finished_reason in ("eos", "length")]
    lat = [finish_t[r.rid] - submit_t[r.rid] for r in results
           if r.rid in finish_t]
    good_tokens = sum(len(r.tokens) for r in done)
    rec = {
        "fault": fault_spec or "none",
        "wall_s": wall,
        "goodput_tok_s": good_tokens / max(wall, 1e-9),
        "good_tokens": good_tokens,
        "latency_p50_s": _percentile(lat, 50),
        "latency_p99_s": _percentile(lat, 99),
        "rid_accounting_exact": rids_ok,
        "replica_states": router.replica_states,
        **router.stats,
    }
    print(f"router_failover,{name},goodput_tok_s="
          f"{rec['goodput_tok_s']:.1f},p99_s={rec['latency_p99_s']:.3f},"
          f"completed={rec['completed']},shed={rec['shed']},"
          f"timed_out={rec['timed_out']},failovers={rec['failovers']},"
          f"accounting_ok={rids_ok}", flush=True)
    return rec


def _shed_scenario(api, params, cfgv, reqs):
    """Bounded queues + tight deadlines: overflow sheds at the door."""
    from repro.serve.router import ReplicaRouter

    router = ReplicaRouter(
        api, params, replicas=cfgv["replicas"], n_slots=cfgv["n_slots"],
        capacity=cfgv["prompt_len"] + cfgv["max_new"] + 8,
        max_queue=1, est_step_s=5.0)
    requests = reqs(deadline_s=30.0)
    for r in requests:
        router.submit(r)
    while router.step():
        pass
    router.close()
    results = sorted(router.results, key=lambda r: r.rid)
    rec = {
        "max_queue": 1, "deadline_s": 30.0,
        "rid_accounting_exact":
            [r.rid for r in results] == sorted(r.rid for r in requests),
        **router.stats,
    }
    print(f"router_failover,shed,completed={rec['completed']},"
          f"shed={rec['shed']},timed_out={rec['timed_out']},"
          f"accounting_ok={rec['rid_accounting_exact']}", flush=True)
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_router.json")
    ap.add_argument("--smoke", action="store_true",
                    help="small grid for the CI smoke lane")
    args = ap.parse_args(argv)
    os.environ.setdefault("JAX_PLATFORMS", "cpu")

    cfgv = SMOKE if args.smoke else FULL
    api, params, cfg, reqs = _build(cfgv)
    t = cfgv["fault_tick"]
    scenarios = {
        "baseline": _scenario(api, params, cfgv, reqs, "baseline", ""),
        "kill": _scenario(api, params, cfgv, reqs, "kill", f"kill@{t}:0"),
        "stall": _scenario(api, params, cfgv, reqs, "stall",
                           f"stall@{t}:0:{cfgv['stall_s']}"),
        "nanlogits": _scenario(api, params, cfgv, reqs, "nanlogits",
                               f"nanlogits@{t}:0"),
    }
    base = scenarios["baseline"]["goodput_tok_s"]
    for name, s in scenarios.items():
        s["goodput_vs_baseline"] = s["goodput_tok_s"] / max(base, 1e-9)
    rec = {
        "bench": "router_failover_sweep",
        "smoke": bool(args.smoke),
        "arch": cfg.name,
        "config": cfgv,
        "scenarios": scenarios,
        "load_shed": _shed_scenario(api, params, cfgv, reqs),
    }
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"router_failover,done,out={args.out},"
          f"kill_vs_baseline={scenarios['kill']['goodput_vs_baseline']:.2f}")
    return 0


def run(out: str = "BENCH_router.json") -> None:
    """benchmarks.run entry."""
    main(["--out", out])


if __name__ == "__main__":
    sys.exit(main())
