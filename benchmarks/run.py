"""Benchmark harness entry point: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--skip-fig4]

Emits CSV lines (benchmark,key=value,...) for:
  fig4   — epochs-to-converge vs global batch (REAL CPU convergence runs)
  fig3   — the paper's illustrative hybrid-crossover scenario
  table1 — 2-way MP per-step speedups (DLPlacer / pipeline / tensor-MP)
  fig5   — hybrid vs DP-only projections + the paper's headline claims
  fig8   — DLPlacer prediction vs simulated silicon
  roofline — the dry-run roofline table (if results/dryrun exists)
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    t0 = time.time()
    full = "--full" in sys.argv
    print("benchmark,start")

    from benchmarks import (fig3_example, fig5_hybrid, fig8_dlplacer,
                            table1_mp_speedup)
    table1_mp_speedup.run()
    fig3_example.run()
    fig5_hybrid.run()
    fig8_dlplacer.run()

    if "--skip-fig4" not in sys.argv:
        from benchmarks import fig4_epochs
        fig4_epochs.run(quick=not full)

    try:
        from benchmarks import roofline_report
        roofline_report.run()
    except FileNotFoundError:
        print("roofline,skipped (run launch/dryrun.py first)")

    if full:
        from benchmarks import (collective_overlap_sweep,
                                context_parallel_sweep, fault_recovery_sweep,
                                pipeline_schedule_sweep,
                                router_failover_sweep)
        pipeline_schedule_sweep.run()
        collective_overlap_sweep.run()
        context_parallel_sweep.run()
        fault_recovery_sweep.run()
        router_failover_sweep.run()

    print(f"benchmark,done,wall_s={time.time() - t0:.1f}")


if __name__ == "__main__":
    main()
