"""Collective-overlap sweep: overlapped collective-matmul + bucketed DP sync
vs the GSPMD monolithic-collective lanes, on a forced host mesh.

    PYTHONPATH=src python -m benchmarks.collective_overlap_sweep [--smoke]

Emits ``BENCH_collectives.json`` with three sections:

- **tensor_mp** — a stack of Megatron column/row-parallel MLP layers run
  fwd+bwd under (a) GSPMD shardings (monolithic all-reduce per row-parallel
  matmul) and (b) the overlap-scheduled chunked ``ppermute`` rings
  (``parallel.collectives``; ``models.layers.mlp_apply_overlapped``) over a
  chunk-count sweep.  Per lane: measured step time, collective op counts and
  per-chip wire bytes parsed from the compiled HLO — the overlapped lane's
  wire bytes are ASSERTED equal to the analytic ring model (fwd: gather(x) +
  scatter(out); bwd: gather(dy) + scatter(dx) + re-gather(x) = 5 rings of
  (m-1)/m * |x| each per layer), and its HLO must contain no monolithic
  all-gather / all-reduce on the matmul hot path (every >unit-group
  collective is a chunk-sized collective-permute).

- **dp_sync** — the same stack replicated over a pure-DP mesh: GSPMD's fused
  gradient all-reduce vs ``bucketed_grad_sync``'s per-bucket reduce-scatter
  + all-gather split, with the bucket count swept via the bucket size.

- **planner_crossover** — the ``HybridPlanner`` DP-vs-hybrid crossover
  device count under each comm runtime (the BENCH-visible form of the
  pinned golden in ``tests/test_planner_golden.py``).

``overlap_constant_proxy`` summarizes the best overlapped-vs-gspmd step-time
ratio; it seeds ``core.comm.MEASURED_OVERLAP`` but the host-mesh CPU backend
has no async collectives, so re-calibrate the constant on real ICI hardware
(the same caveat as BENCH_pipeline.json's bubble calibration).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

MESH_M = 4          # model-axis shards (= forced host devices)
LAYERS = 4
# full-mode sizing: per-layer matmul time must dominate the host-mesh
# per-collective dispatch overhead for the overlap to be measurable
FULL = dict(d_model=512, d_ff=2048, batch=8, seq=512, chunk_sweep=(1, 2, 4),
            reps=5, warmup=1)
SMOKE = dict(d_model=128, d_ff=512, batch=4, seq=128, chunk_sweep=(1, 2),
             reps=2, warmup=1)


def _measure(cfgv):
    import time

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.roofline import parse_collectives
    from repro.models import layers as L
    from repro.parallel.collectives import bucketed_grad_sync
    from repro.parallel.jaxcompat import make_mesh, set_mesh, shard_map

    m = MESH_M
    d, ff = cfgv["d_model"], cfgv["d_ff"]
    b, t = cfgv["batch"], cfgv["seq"]
    key = jax.random.PRNGKey(0)
    params = [{"wi": jax.random.normal(jax.random.fold_in(key, i),
                                       (d, ff)) * 0.02,
               "wo": jax.random.normal(jax.random.fold_in(key, 100 + i),
                                       (ff, d)) * 0.02}
              for i in range(LAYERS)]
    x = jax.random.normal(jax.random.PRNGKey(1), (b, t, d))

    def _time(compiled, args):
        jax.block_until_ready(compiled(*args))
        for _ in range(cfgv["warmup"]):
            jax.block_until_ready(compiled(*args))
        best = float("inf")
        for _ in range(cfgv["reps"]):
            t0 = time.perf_counter()
            jax.block_until_ready(compiled(*args))
            best = min(best, time.perf_counter() - t0)
        return best

    def stack_loss(p, x, mlp):
        for lp in p:
            x = x + mlp(lp, x)
        return (x ** 2).mean()

    # ---- tensor-MP lanes -------------------------------------------------
    mesh = make_mesh((1, m), ("data", "model"))
    p_sh = [{"wi": NamedSharding(mesh, P(None, "model")),
             "wo": NamedSharding(mesh, P("model", None))}
            for _ in range(LAYERS)]
    x_sh = NamedSharding(mesh, P())

    def gspmd_mlp(lp, x):
        return jax.nn.gelu(x @ lp["wi"]) @ lp["wo"]

    def overlapped_mlp(chunks):
        def mlp(lp, x):
            def local(lp, xl):
                return L.mlp_apply_overlapped(lp, xl, "gelu", axis="model",
                                              axis_size=m, chunks=chunks)
            return shard_map(
                local, mesh=mesh,
                in_specs=({"wi": P(None, "model"), "wo": P("model", None)},
                          P(None, "model", None)),
                out_specs=P(None, "model", None))(lp, x)
        return mlp

    x_bytes = b * t * d * 4
    # fwd: gather(x) + scatter(out); bwd: gather(dy) + scatter(dx) +
    # re-gather(x) for dW — 5 rings of (m-1)/m * |x| per layer
    expected_ring_wire = LAYERS * 5 * (m - 1) / m * x_bytes
    points = []
    with set_mesh(mesh):
        lanes = [("gspmd", None, lambda: gspmd_mlp)]
        lanes += [(f"overlapped", c, lambda c=c: overlapped_mlp(c))
                  for c in cfgv["chunk_sweep"]]
        for lane, chunks, mk in lanes:
            fn = jax.jit(jax.value_and_grad(
                lambda p, x, mlp=mk(): stack_loss(p, x, mlp)),
                in_shardings=(p_sh, x_sh))
            compiled = fn.lower(params, x).compile()
            stats = parse_collectives(compiled.as_text(), default_group=m)
            pt = {"lane": lane, "chunks": chunks,
                  "step_time_s": _time(compiled, (params, x)),
                  "ops": stats.ops, "wire_bytes": stats.wire_bytes}
            if lane == "overlapped":
                # Wire must match the analytic ring model: at most the 5
                # rings/layer above, at least 4 (XLA may CSE the backward
                # re-gather of x against the forward gather), plus sub-KB
                # scalar-loss psums.  And the hot path must be chunk-sized
                # permutes only: an all-gather / all-reduce carrying an
                # activation-sized payload over a real (>1) replica group
                # would be a monolithic collective GSPMD smuggled back in
                # (unit-group psums from the shard_map transpose carry zero
                # wire and are fine).
                pt["expected_wire_bytes"] = expected_ring_wire
                assert (0.75 * expected_ring_wire <= stats.wire_bytes
                        <= expected_ring_wire + 1024), \
                    (stats.wire_bytes, expected_ring_wire, stats.ops)
                from repro.core.roofline import (_GROUPS_IOTA_RE,
                                                 _GROUPS_LIST_RE,
                                                 _tensor_bytes)
                chunk_bytes = x_bytes // m

                def group_size(ln):
                    g = _GROUPS_IOTA_RE.search(ln)
                    if g:
                        return int(g.group(2))
                    g = _GROUPS_LIST_RE.search(ln)
                    if g:
                        return len([s for s in g.group(1).split(",")
                                    if s.strip()])
                    return m

                mono = [ln for ln in stats.lines
                        if ("all-reduce" in ln or "all-gather" in ln)
                        and group_size(ln) > 1
                        and _tensor_bytes(ln) >= chunk_bytes]
                assert not mono, mono
            points.append(pt)
            print(f"collective_sweep,lane={lane},chunks={chunks},"
                  f"step_s={pt['step_time_s']:.4f},"
                  f"wire={pt['wire_bytes']:.0f}", flush=True)
    t_gspmd = points[0]["step_time_s"]
    best_ov = min(p["step_time_s"] for p in points if p["lane"] == "overlapped")
    tensor_mp = {
        "points": points,
        "gspmd_step_s": t_gspmd,
        "best_overlapped_step_s": best_ov,
        "overlapped_le_gspmd": bool(best_ov <= t_gspmd),
        "overlap_constant_proxy": max(0.0, 1.0 - best_ov / t_gspmd),
    }

    # ---- DP bucketed grad-sync lanes ------------------------------------
    dmesh = make_mesh((m, 1), ("data", "model"))
    grad_bytes = sum(p.size * 4 for lp in params for p in lp.values())
    dp_points = []
    with set_mesh(dmesh):
        dp_sh = [{"wi": NamedSharding(dmesh, P()),
                  "wo": NamedSharding(dmesh, P())} for _ in range(LAYERS)]
        bx_sh = NamedSharding(dmesh, P("data"))

        def mono_fn(p, xb):
            return jax.value_and_grad(
                lambda p: stack_loss(p, xb, gspmd_mlp))(p)

        def bucketed_fn(bucket_bytes):
            def fn(p, xb):
                def local(p, xl):
                    loss, g = jax.value_and_grad(
                        lambda p: stack_loss(p, xl, gspmd_mlp))(p)
                    g = bucketed_grad_sync(g, dp_axis="data", dp_size=m,
                                           bucket_bytes=bucket_bytes)
                    g = jax.tree.map(lambda v: v / m, g)
                    return jax.lax.pmean(loss, "data"), g
                return shard_map(local, mesh=dmesh,
                                 in_specs=(P(), P("data")),
                                 out_specs=(P(), P()))(p, xb)
            return fn

        # "monolithic" = the manual sync with ONE bucket — the
        # apples-to-apples baseline for bucketing (same shard_map codegen,
        # only the bucket count differs); GSPMD's fused all-reduce lane is
        # reported alongside for the cross-runtime picture
        for lane, fn, bkt in (
                [("gspmd", mono_fn, None),
                 ("monolithic", bucketed_fn(grad_bytes), float(grad_bytes))]
                + [(f"bucketed", bucketed_fn(grad_bytes / k), grad_bytes / k)
                   for k in (4, 8)]):
            compiled = jax.jit(fn, in_shardings=(dp_sh, bx_sh)) \
                .lower(params, x).compile()
            stats = parse_collectives(compiled.as_text(), default_group=m)
            dp_points.append({
                "lane": lane, "bucket_bytes": bkt,
                "n_buckets": (None if bkt is None
                              else max(1, round(grad_bytes / bkt))),
                "step_time_s": _time(compiled, (params, x)),
                "ops": stats.ops, "wire_bytes": stats.wire_bytes})
            print(f"collective_sweep,dp_lane={lane},bucket={bkt},"
                  f"step_s={dp_points[-1]['step_time_s']:.4f},"
                  f"ops={stats.ops}", flush=True)
    dp_best = min(p["step_time_s"] for p in dp_points if p["lane"] == "bucketed")
    t_mono = next(p["step_time_s"] for p in dp_points
                  if p["lane"] == "monolithic")
    dp_sync = {"points": dp_points, "grad_bytes": grad_bytes,
               "gspmd_step_s": dp_points[0]["step_time_s"],
               "monolithic_step_s": t_mono,
               "best_bucketed_step_s": dp_best,
               "bucketed_le_monolithic": bool(dp_best <= t_mono),
               "best_bucketed_over_gspmd":
                   dp_best / dp_points[0]["step_time_s"]}
    return tensor_mp, dp_sync


def _planner_crossover():
    # llama: an arch the overlapped runtime executes, so the measured
    # overlap legitimately moves its crossover (inception's CNN blocks fall
    # back to GSPMD and must not move — see test_planner_golden.py)
    from repro.configs import get_config
    from repro.core.planner import HybridPlanner, default_epoch_model
    out = {}
    cfg = get_config("llama3_2_1b")
    for rt in ("gspmd", "overlapped"):
        planner = HybridPlanner(cfg, epoch_model=default_epoch_model(cfg),
                                comm_runtime=rt)
        out[rt] = {"crossover_m2": planner.crossover(2),
                   "crossover_m4": planner.crossover(4),
                   "best_256_speedup": planner.best(256).speedup,
                   "best_256_kind": planner.best(256).mp_kind}
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_collectives.json")
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes / few reps for the CI smoke lane")
    args = ap.parse_args(argv)

    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={MESH_M}"
            .strip())
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    cfgv = SMOKE if args.smoke else FULL
    tensor_mp, dp_sync = _measure(cfgv)
    rec = {
        "bench": "collective_overlap_sweep",
        "smoke": bool(args.smoke),
        "mesh_m": MESH_M, "layers": LAYERS, **{k: cfgv[k] for k in
                                               ("d_model", "d_ff", "batch",
                                                "seq")},
        "tensor_mp": tensor_mp,
        "dp_sync": dp_sync,
        "planner_crossover": _planner_crossover(),
    }
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"collective_sweep,done,out={args.out},"
          f"overlapped_le_gspmd={tensor_mp['overlapped_le_gspmd']},"
          f"overlap_proxy={tensor_mp['overlap_constant_proxy']:.3f},"
          f"bucketed_le_monolithic={dp_sync['bucketed_le_monolithic']}")
    return 0


def run(out: str = "BENCH_collectives.json") -> None:
    """benchmarks.run entry: re-exec in a subprocess so the forced host
    device count does not fight the already-initialized jax here."""
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={MESH_M}",
               JAX_PLATFORMS="cpu")
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.collective_overlap_sweep",
         "--out", out], env=env, text=True, capture_output=True, timeout=1800)
    sys.stdout.write(r.stdout)
    if r.returncode:
        sys.stdout.write(r.stderr[-2000:])
        print("collective_sweep,failed")


if __name__ == "__main__":
    sys.exit(main())
