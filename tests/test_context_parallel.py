"""Context-parallel ring attention (ISSUE 8): the sequence-sharded KV
ppermute ring vs unsharded attention at fp32 round-off (values AND custom-vjp
grads over the ring-size x mask x GQA grid), the end-to-end CP train step vs
single-device, the HLO assertion that the CP hot path carries only
collective-permutes (no monolithic all-gather of K/V), planner/plan/CLI
gating for the new ``mp_kind='context'`` axis, and the serve engine's
CP-routed chunked prefill."""
import json
import os
import subprocess
import sys
import textwrap
import warnings

import pytest

from repro.configs import get_config
from repro.core.comm import (HardwareModel, cp_ring_time,
                             load_measured_overlap)
from repro.core.planner import (HybridPlanner, context_mp_supported,
                                cp_step_speedup, default_epoch_model)
from repro.launch.train import parse_parallel
from repro.parallel.plan import ParallelPlan
from repro.parallel.sharding import ShardingRules

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_subprocess(code: str):
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


# ---------------------------------------------------------------------------
# pure (no-device) units
# ---------------------------------------------------------------------------

def test_plan_context_validation():
    p = ParallelPlan(mp_kind="context")
    assert p.is_context and not p.is_pipeline
    desc = p.describe(FakeMesh({"data": 2, "model": 4}))
    assert "kv ring" in desc, desc
    with pytest.raises(ValueError, match="mp_kind"):
        ParallelPlan(mp_kind="sequence")
    # the ring schedules its own collectives; the overlapped matmul runtime
    # has no meaning on a context axis
    with pytest.raises(ValueError, match="context"):
        ParallelPlan(mp_kind="context", comm_runtime="overlapped")


def test_sharding_rules_context_replicates_params():
    """Under a context plan the model axis hosts the KV ring, NOT tensor
    shards: every parameter spec must stay off the model axis (replicated
    across the ring), while the batch still shards over DP."""
    import jax
    from repro.models import build_model

    cfg = get_config("llama3_2_1b")
    api = build_model(cfg)
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = ShardingRules(cfg, mesh, ParallelPlan(mp_kind="context"))
    specs = rules.params_specs(jax.eval_shape(api.init, jax.random.PRNGKey(0)))
    used = {a for s in jax.tree.leaves(specs, is_leaf=lambda x: x is None)
            if s is not None for a in s if a is not None}
    assert "model" not in used, used
    # tensor plan on the same mesh does shard params over the model axis
    t_specs = ShardingRules(cfg, mesh, ParallelPlan()).params_specs(
        jax.eval_shape(api.init, jax.random.PRNGKey(0)))
    t_used = {a for s in jax.tree.leaves(t_specs, is_leaf=lambda x: x is None)
              if s is not None for a in s if a is not None}
    assert "model" in t_used, t_used


def test_cp_supported_gating():
    """The ring only engages for homogeneous dense decoders with the
    sequence divisible by the ring size; everything else falls back."""
    from repro.models.transformer import ParallelCtx, cp_supported

    def ctx(m):
        return ParallelCtx(mesh=FakeMesh({"data": 2, "model": m}),
                           batch_axes=("data",), model_axis=None,
                           context_axis="model")

    dense = get_config("llama3_2_1b").reduced()
    assert cp_supported(dense, ctx(2), t=32)
    assert cp_supported(dense, ctx(4), t=32)
    assert not cp_supported(dense, ctx(1), t=32)
    assert not cp_supported(dense, ctx(4), t=30)    # seq % ring
    assert not cp_supported(dense, None, t=32)
    import dataclasses
    capped = dataclasses.replace(dense, attn_logit_softcap=30.0)
    assert not cp_supported(capped, ctx(2), t=32)   # no capped softmax fold
    assert not cp_supported(get_config("granite_moe_1b_a400m").reduced(),
                            ctx(2), t=32)
    assert not cp_supported(get_config("rwkv6_7b").reduced(), ctx(2), t=32)


def test_parse_parallel_cp_grammar():
    cfg = get_config("llama3_2_1b")
    plan, mp, dp = parse_parallel("dp=2,cp=4", 8, cfg)
    assert plan.mp_kind == "context" and mp == 4 and dp == 2
    # --context-parallel reinterprets mp= as the ring size
    plan2, mp2, _ = parse_parallel("dp=2,mp=4", 8, cfg, context_parallel=True)
    assert plan2.mp_kind == "context" and mp2 == 4
    with pytest.raises(SystemExit, match="cp="):
        parse_parallel("cp=2,mp=2", 4, cfg)
    with pytest.raises(SystemExit, match="cp="):
        parse_parallel("cp=2,pipe=2", 4, cfg)
    # without the cp key or the flag, mp= stays tensor
    plan3, _, _ = parse_parallel("dp=2,mp=4", 8, cfg)
    assert plan3.mp_kind == "tensor"


def test_planner_context_axis():
    """The planner searches context points: cp_speedup only holds ring
    sizes that divide the sequence, the context kind appears in choices,
    and its memory model replicates params (only activations shard 1/m)."""
    cfg = get_config("llama3_2_1b")
    pl = HybridPlanner(cfg, epoch_model=default_epoch_model(cfg),
                       seq_len=4096)
    assert pl.run.cp_speedup, "no context points searched"
    assert all(4096 % m == 0 for m in pl.run.cp_speedup)
    assert all(1.0 < su <= m for m, su in pl.run.cp_speedup.items()), \
        pl.run.cp_speedup
    choices = pl.choices(64)
    kinds = {c.mp_kind for c in choices}
    assert "context" in kinds, kinds
    ctx_choice = next(c for c in choices if c.mp_kind == "context")
    assert ctx_choice.mp in pl.run.cp_speedup
    # non-divisible sequence filters the ring sizes out entirely
    pl_odd = HybridPlanner(cfg, epoch_model=default_epoch_model(cfg),
                           seq_len=4097)
    assert not pl_odd.run.cp_speedup
    assert all(c.mp_kind != "context" for c in pl_odd.choices(64))
    # archs without the dense-decoder CP path never get context points
    assert not context_mp_supported(get_config("granite_moe_1b_a400m"))
    moe = HybridPlanner(get_config("granite_moe_1b_a400m"),
                        epoch_model=default_epoch_model(
                            get_config("granite_moe_1b_a400m")))
    assert not moe.run.cp_speedup


def test_cp_ring_time_and_speedup_model():
    hw = HardwareModel()
    t2 = cp_ring_time(1 << 20, 2, hw)
    t4 = cp_ring_time(1 << 20, 4, hw)
    assert 0 < t2 < t4            # more hops, more wire time
    assert cp_ring_time(1 << 20, 1, hw) == 0.0
    cfg = get_config("llama3_2_1b")
    su2 = cp_step_speedup(cfg, 2, hw)
    su4 = cp_step_speedup(cfg, 4, hw)
    assert 1.0 < su2 < 2.0 and su2 < su4 < 4.0, (su2, su4)


def test_load_measured_overlap(tmp_path, monkeypatch):
    """Satellite 1: the planner's overlap constant comes from the measured
    BENCH_collectives.json artifact when present, clamped sane, with the
    0.6 paper-era fallback when absent or malformed."""
    good = tmp_path / "bench.json"
    good.write_text(json.dumps(
        {"tensor_mp": {"overlap_constant_proxy": 0.25}}))
    assert load_measured_overlap(str(good))["overlapped"] == 0.25
    monkeypatch.setenv("REPRO_BENCH_COLLECTIVES", str(good))
    assert load_measured_overlap()["overlapped"] == 0.25
    monkeypatch.delenv("REPRO_BENCH_COLLECTIVES")
    missing = load_measured_overlap(str(tmp_path / "missing.json"))
    assert missing == {"gspmd": 0.0, "overlapped": 0.6}
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert load_measured_overlap(str(bad))["overlapped"] == 0.6
    huge = tmp_path / "huge.json"
    huge.write_text(json.dumps(
        {"tensor_mp": {"overlap_constant_proxy": 7.0}}))
    assert load_measured_overlap(str(huge))["overlapped"] == 0.95  # clamped
    # the checked-in artifact (repo root) IS the session default
    from repro.core.comm import MEASURED_OVERLAP
    assert 0.0 <= MEASURED_OVERLAP["overlapped"] <= 0.95


# ---------------------------------------------------------------------------
# multi-device equivalence (subprocesses)
# ---------------------------------------------------------------------------

def test_ring_attention_matches_reference_grid():
    """Acceptance: ring values AND custom-vjp grads == unsharded attention
    at fp32 round-off over (ring size x causal/window/bidirectional x GQA),
    with rows spread across ring devices."""
    out = _run_subprocess("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import functools
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.parallel.jaxcompat import make_mesh, set_mesh, shard_map
        from repro.models.layers import attention
        from repro.parallel.context import ring_attention

        B, T, HQ, HKV, HD = 2, 32, 4, 2, 8
        key = jax.random.PRNGKey(0)
        kq, kk, kv = jax.random.split(key, 3)
        q = jax.random.normal(kq, (B, T, HQ, HD))
        k = jax.random.normal(kk, (B, T, HKV, HD))
        v = jax.random.normal(kv, (B, T, HKV, HD))

        for m in (2, 4):
            mesh = make_mesh((1, m), ("data", "model"))
            for causal, window in ((True, 0), (True, 8), (False, 0)):
                def loss_ref(q, k, v):
                    o = attention(q, k, v, causal=causal, window=window)
                    return (o.astype(jnp.float32) ** 2).sum()

                def loss_ring(q, k, v):
                    fn = functools.partial(ring_attention, axis="model",
                                           axis_size=m, causal=causal,
                                           window=window)
                    o = shard_map(fn, mesh=mesh,
                                  in_specs=(P(None, "model", None, None),) * 3,
                                  out_specs=P(None, "model", None, None))(
                                      q, k, v)
                    return (o.astype(jnp.float32) ** 2).sum()

                lr, gr = jax.value_and_grad(loss_ref, argnums=(0, 1, 2))(
                    q, k, v)
                with set_mesh(mesh):
                    l, g = jax.jit(jax.value_and_grad(
                        loss_ring, argnums=(0, 1, 2)))(q, k, v)
                err_l = abs(float(l) - float(lr)) / abs(float(lr))
                err_g = max(float(jnp.abs(a - b).max())
                            for a, b in zip(g, gr))
                assert err_l < 1e-5 and err_g < 1e-4, (
                    m, causal, window, err_l, err_g)
                print("OK", m, causal, window)
    """)
    assert out.count("OK") == 6


def test_cp_train_step_matches_single_device():
    """Acceptance (tentpole pin): one optimizer step on a dp x ring mesh ==
    the single-device step — loss at fp32 round-off, params at norm-relative
    round-off — through the full make_train_step path."""
    _run_subprocess("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from repro.parallel.jaxcompat import make_mesh, set_mesh
        from repro.configs import get_config
        from repro.models import build_model
        from repro.parallel.plan import ParallelPlan
        from repro.train.steps import (_make_pctx, init_train_state,
                                       make_train_step, shardings_for)
        from repro.optim import adamw, warmup_cosine

        cfg = get_config("llama3_2_1b").reduced()
        api = build_model(cfg, remat=False)
        opt = adamw(warmup_cosine(1e-3, 2, 10))
        key = jax.random.PRNGKey(0)
        state = init_train_state(api, opt, key)
        batch = {"tokens": jax.random.randint(key, (4, 64), 0,
                          cfg.vocab_size, dtype=jnp.int32),
                 "labels": jax.random.randint(key, (4, 64), 0,
                          cfg.vocab_size, dtype=jnp.int32)}
        ref_step = make_train_step(api, opt)
        ref_state, ref_metrics = jax.jit(ref_step)(state, batch)

        mesh = make_mesh((2, 4), ("data", "model"))
        plan = ParallelPlan(mp_kind="context")
        pctx = _make_pctx(mesh, plan, batch_shardable=True)
        assert pctx.context_axis == "model" and pctx.model_axis is None
        i32 = jnp.int32
        specs = {"tokens": jax.ShapeDtypeStruct((4, 64), i32),
                 "labels": jax.ShapeDtypeStruct((4, 64), i32)}
        s_sh, b_sh = shardings_for(api, mesh, plan, opt, specs)
        step = make_train_step(api, opt, mesh=mesh, plan=plan, pctx=pctx)
        import warnings
        with set_mesh(mesh), warnings.catch_warnings():
            warnings.simplefilter("error")      # the ring MUST engage
            cp_state, cp_metrics = jax.jit(
                step, in_shardings=(s_sh, b_sh))(state, batch)
        err_l = abs(float(ref_metrics["loss"]) - float(cp_metrics["loss"]))
        assert err_l < 5e-5, err_l
        def nrel(a, b):
            d = float(jnp.linalg.norm((a - b).ravel()))
            n = float(jnp.linalg.norm(a.ravel()))
            return d / max(n, 1e-8)
        err_p = max(jax.tree.leaves(jax.tree.map(
            nrel, ref_state.params, cp_state.params)))
        assert err_p < 5e-5, err_p
        print("OK", err_l, err_p)
    """)


def test_cp_hot_path_ring_only_hlo():
    """Acceptance (HLO): growing the layer count on the CP path grows only
    collective-permutes — no per-layer all-gather of K/V (the gathered
    baseline is exactly what CP exists to avoid)."""
    _run_subprocess("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import dataclasses
        import jax, jax.numpy as jnp
        from repro.parallel.jaxcompat import make_mesh, set_mesh
        from repro.configs import get_config
        from repro.models import build_model
        from repro.models.transformer import ParallelCtx
        from repro.parallel.plan import ParallelPlan
        from repro.parallel.sharding import ShardingRules
        from repro.core.roofline import parse_collectives

        base = get_config("llama3_2_1b").reduced()
        mesh = make_mesh((1, 4), ("data", "model"))

        def collect(n_layers):
            cfg = dataclasses.replace(base, n_layers=n_layers)
            api = build_model(cfg, remat=False)
            key = jax.random.PRNGKey(0)
            params = api.init(key)
            batch = {"tokens": jax.random.randint(key, (2, 32), 0,
                              cfg.vocab_size, dtype=jnp.int32),
                     "labels": jax.random.randint(key, (2, 32), 0,
                              cfg.vocab_size, dtype=jnp.int32)}
            pctx = ParallelCtx(mesh=mesh, batch_axes=("data",),
                               model_axis=None, context_axis="model")
            rules = ShardingRules(cfg, mesh, ParallelPlan(mp_kind="context"))
            p_sh = rules.params_shardings(jax.eval_shape(api.init, key))
            b_sh = rules.batch_shardings(jax.eval_shape(lambda: batch))
            from repro.models import layers as L
            L.set_analysis_unroll(True)
            try:
                with set_mesh(mesh):
                    comp = jax.jit(jax.grad(
                        lambda p, b: api.loss_fn(p, b, pctx)[0]),
                        in_shardings=(p_sh, b_sh)).lower(
                            params, batch).compile()
            finally:
                L.set_analysis_unroll(False)
            return parse_collectives(comp.as_text(), default_group=4)

        c2, c4 = collect(2), collect(4)
        dcp = c4.ops.get("collective-permute", 0) - \\
            c2.ops.get("collective-permute", 0)
        dag = c4.ops.get("all-gather", 0) - c2.ops.get("all-gather", 0)
        assert dcp > 0, (c2.ops, c4.ops)
        assert dag == 0, (c2.ops, c4.ops)
        print("OK", c2.ops, c4.ops)
    """)


def test_cp_fallback_warns_and_matches():
    """A sequence the ring size does not divide must fall back to GSPMD's
    gathered attention WITH the '[context]' perf-cliff warning — and the
    fallback still computes the right loss."""
    _run_subprocess("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        import warnings
        import jax, jax.numpy as jnp
        from repro.parallel.jaxcompat import make_mesh, set_mesh
        from repro.configs import get_config
        from repro.models import build_model
        from repro.models.transformer import ParallelCtx
        from repro.parallel.plan import ParallelPlan
        from repro.parallel.sharding import ShardingRules

        cfg = get_config("llama3_2_1b").reduced()
        api = build_model(cfg, remat=False)
        key = jax.random.PRNGKey(0)
        params = api.init(key)
        batch = {"tokens": jax.random.randint(key, (2, 33), 0,
                          cfg.vocab_size, dtype=jnp.int32),
                 "labels": jax.random.randint(key, (2, 33), 0,
                          cfg.vocab_size, dtype=jnp.int32)}
        ref = float(api.loss_fn(params, batch)[0])
        mesh = make_mesh((1, 2), ("data", "model"))
        pctx = ParallelCtx(mesh=mesh, batch_axes=("data",),
                           model_axis=None, context_axis="model")
        rules = ShardingRules(cfg, mesh, ParallelPlan(mp_kind="context"))
        p_sh = rules.params_shardings(jax.eval_shape(api.init, key))
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            with set_mesh(mesh):
                l = float(jax.jit(lambda p, b: api.loss_fn(p, b, pctx)[0],
                                  in_shardings=(p_sh, None)).lower(
                    params, batch).compile()(params, batch))
            msgs = [str(x.message) for x in w
                    if "[context]" in str(x.message)]
        assert msgs, "no [context] fallback warning for seq 33 on a 2-ring"
        assert "33" in msgs[0] and "2" in msgs[0], msgs[0]
        assert abs(l - ref) < 5e-5, (l, ref)
        print("OK", l, ref)
    """)


def test_continuous_engine_cp_prefill_matches_reference():
    """Satellite 2: the continuous engine with ``context_axis`` routes its
    prefill chunks through the sequence-sharded KV ring and still produces
    exactly the single-device tokens/logprobs."""
    out = _run_subprocess("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import build_model
        from repro.models import transformer as tf_mod
        from repro.parallel.jaxcompat import make_mesh
        from repro.serve import ContinuousEngine, Request

        cfg = get_config("llama3_2_1b").reduced()
        api = build_model(cfg, remat=False)
        params = api.init(jax.random.PRNGKey(0))
        mesh = make_mesh((1, 2), ("data", "model"))
        assert tf_mod.prefill_chunk_cp_supported(cfg, mesh, "model", 4)
        assert not tf_mod.prefill_chunk_cp_supported(cfg, mesh, "model", 3)

        reqs = lambda: [
            Request(rid=0, tokens=list(range(1, 10)), max_new_tokens=5),
            Request(rid=1, tokens=list(range(11, 16)), max_new_tokens=5)]
        ref = ContinuousEngine(api, params, n_slots=2, capacity=32,
                               prefill_chunk=4).run(reqs())
        cp = ContinuousEngine(api, params, n_slots=2, capacity=32,
                              prefill_chunk=4, mesh=mesh,
                              context_axis="model",
                              batch_axes=("data",)).run(reqs())
        for a, b in zip(ref, cp):
            assert a.tokens == b.tokens, (a.tokens, b.tokens)
            np.testing.assert_allclose(a.logprobs, b.logprobs,
                                       rtol=2e-4, atol=2e-4)
        print("CP_OK")
    """)
    assert "CP_OK" in out
