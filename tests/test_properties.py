"""Seeded property sweeps over the system's invariants (the offline stand-in
for hypothesis-based tests — see DESIGN.md §7)."""
import math

import numpy as np
import pytest

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.core.analytical import (TrainingRun, crossover_device_count,
                                   speedup_dp, speedup_hybrid)
from repro.core.comm import HardwareModel, ring_all_reduce_time
from repro.core.dlplacer import DFG, HardwareGraph, OpCost, list_schedule
from repro.core.planner import HybridPlanner, default_epoch_model, mp_step_speedup
from repro.core.roofline import model_flops
from repro.core.stateff import EpochModel, EpochTable


def run_with(b_crit, su2=1.3, alpha=2.0):
    return TrainingRun(name="p", t1=0.1, grad_bytes=1e8, mini_batch=64,
                       epoch_model=EpochModel(4.0, b_crit, alpha),
                       dataset_size=10 ** 6, mp_speedup={2: su2},
                       se_perfect=True)


@pytest.mark.parametrize("seed", range(8))
def test_crossover_monotone_in_critical_batch(seed):
    """Earlier statistical-efficiency cliff (smaller b_crit) => crossover at
    the same or FEWER devices."""
    rng = np.random.default_rng(seed)
    b1 = float(rng.uniform(256, 2048))
    b2 = b1 * float(rng.uniform(2, 8))
    x1 = crossover_device_count(run_with(b1), m=2, max_devices=2 ** 16)
    x2 = crossover_device_count(run_with(b2), m=2, max_devices=2 ** 16)
    if x1 is not None and x2 is not None:
        assert x1 <= x2


@pytest.mark.parametrize("seed", range(8))
def test_hybrid_speedup_monotone_in_su_m(seed):
    rng = np.random.default_rng(100 + seed)
    lo, hi = sorted(rng.uniform(1.01, 1.99, size=2))
    r_lo, r_hi = run_with(1024, su2=float(lo)), run_with(1024, su2=float(hi))
    for n in (8, 64, 512):
        assert speedup_hybrid(r_hi, n, 2) >= speedup_hybrid(r_lo, n, 2)


def test_epoch_table_interpolation_properties():
    t = EpochTable.from_dict({256: 4.0, 1024: 6.0, 4096: 20.0})
    # exact at knots
    assert t.epochs(256) == 4.0 and t.epochs(4096) == 20.0
    # monotone between knots
    xs = np.geomspace(256, 4096, 33)
    es = [t.epochs(float(x)) for x in xs]
    assert all(b >= a - 1e-9 for a, b in zip(es, es[1:]))
    # geometric interpolation stays within bracket
    assert 4.0 <= t.epochs(512) <= 6.0


@pytest.mark.parametrize("n", [2, 3, 7, 16, 255])
def test_ring_all_reduce_bounded_by_2x_bandwidth(n):
    t = ring_all_reduce_time(1e9, n, 1e11, 0.0)
    assert t <= 2 * 1e9 / 1e11 + 1e-12
    assert t >= 1e9 / 1e11 * (n - 1) / n


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_mp_speedup_bounds(arch):
    """1 <= SU^M <= M for every arch and M (no superlinear MP)."""
    hw = HardwareModel()
    cfg = get_config(arch)
    for m in (2, 4, 8, 16):
        su = mp_step_speedup(cfg, m, hw)
        assert 1.0 <= su <= m, (arch, m, su)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_planner_best_dominates_dp_only(arch):
    """The planner's choice is never worse than DP-only at the same budget."""
    cfg = get_config(arch)
    pl = HybridPlanner(cfg, epoch_model=default_epoch_model(cfg),
                       se_perfect=False)
    for d in (64, 512):
        best = pl.best(d)
        dp_only = speedup_hybrid(pl.run, d, 1)
        assert best.speedup >= dp_only - 1e-9


@pytest.mark.parametrize("seed", range(6))
def test_list_schedule_lower_bounds(seed):
    """Any placement's makespan >= max(critical path, work/devices)."""
    rng = np.random.default_rng(200 + seed)
    n = int(rng.integers(5, 12))
    nodes = {f"n{i}": OpCost(float(rng.uniform(1e8, 1e9)), 1e4)
             for i in range(n)}
    edges = [(f"n{i}", f"n{j}") for i in range(n) for j in range(i + 1, n)
             if rng.random() < 0.3]
    dfg = DFG(nodes, edges)
    hw = HardwareGraph(n_devices=2)
    placement = {k: int(rng.integers(0, 2)) for k in nodes}
    ms = list_schedule(dfg, hw, placement)
    work = sum(c.flops for c in nodes.values()) / hw.flops_per_s
    assert ms >= work / 2 - 1e-9


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape", list(INPUT_SHAPES))
def test_model_flops_positive_and_scaling(arch, shape):
    cfg = get_config(arch)
    f = model_flops(cfg, INPUT_SHAPES[shape])
    assert f > 0
    if shape == "train_4k":
        # at least 6 * active params * tokens
        assert f >= 6 * cfg.n_active_params() * 4096 * 256 * 0.99


def test_fig3_benchmark_claims():
    from benchmarks.fig3_example import run
    assert run()
