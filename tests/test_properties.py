"""Seeded property sweeps over the system's invariants (the offline stand-in
for hypothesis-based tests — see DESIGN.md §7)."""
import math

import numpy as np
import pytest

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.core.analytical import (TrainingRun, crossover_device_count,
                                   speedup_dp, speedup_hybrid,
                                   speedup_pipeline)
from repro.core.comm import HardwareModel, ring_all_reduce_time
from repro.core.dlplacer import DFG, HardwareGraph, OpCost, list_schedule
from repro.core.planner import (HybridPlanner, default_epoch_model,
                                mp_step_speedup, per_device_mem_bytes,
                                pipeline_step_speedup_model)
from repro.core.roofline import model_flops
from repro.core.stateff import EpochModel, EpochTable

PLANNER_ARCHS = ARCH_IDS + ["biglstm", "gnmt", "inception_v3"]
PLANNER_BUDGETS = (64, 256, 1024)


def make_planner(arch):
    cfg = get_config(arch)
    return cfg, HybridPlanner(cfg, epoch_model=default_epoch_model(cfg))


def run_with(b_crit, su2=1.3, alpha=2.0):
    return TrainingRun(name="p", t1=0.1, grad_bytes=1e8, mini_batch=64,
                       epoch_model=EpochModel(4.0, b_crit, alpha),
                       dataset_size=10 ** 6, mp_speedup={2: su2},
                       se_perfect=True)


@pytest.mark.parametrize("seed", range(8))
def test_crossover_monotone_in_critical_batch(seed):
    """Earlier statistical-efficiency cliff (smaller b_crit) => crossover at
    the same or FEWER devices."""
    rng = np.random.default_rng(seed)
    b1 = float(rng.uniform(256, 2048))
    b2 = b1 * float(rng.uniform(2, 8))
    x1 = crossover_device_count(run_with(b1), m=2, max_devices=2 ** 16)
    x2 = crossover_device_count(run_with(b2), m=2, max_devices=2 ** 16)
    if x1 is not None and x2 is not None:
        assert x1 <= x2


@pytest.mark.parametrize("seed", range(8))
def test_hybrid_speedup_monotone_in_su_m(seed):
    rng = np.random.default_rng(100 + seed)
    lo, hi = sorted(rng.uniform(1.01, 1.99, size=2))
    r_lo, r_hi = run_with(1024, su2=float(lo)), run_with(1024, su2=float(hi))
    for n in (8, 64, 512):
        assert speedup_hybrid(r_hi, n, 2) >= speedup_hybrid(r_lo, n, 2)


def test_epoch_table_interpolation_properties():
    t = EpochTable.from_dict({256: 4.0, 1024: 6.0, 4096: 20.0})
    # exact at knots
    assert t.epochs(256) == 4.0 and t.epochs(4096) == 20.0
    # monotone between knots
    xs = np.geomspace(256, 4096, 33)
    es = [t.epochs(float(x)) for x in xs]
    assert all(b >= a - 1e-9 for a, b in zip(es, es[1:]))
    # geometric interpolation stays within bracket
    assert 4.0 <= t.epochs(512) <= 6.0


@pytest.mark.parametrize("n", [2, 3, 7, 16, 255])
def test_ring_all_reduce_bounded_by_2x_bandwidth(n):
    t = ring_all_reduce_time(1e9, n, 1e11, 0.0)
    assert t <= 2 * 1e9 / 1e11 + 1e-12
    assert t >= 1e9 / 1e11 * (n - 1) / n


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_mp_speedup_bounds(arch):
    """1 <= SU^M <= M for every arch and M (no superlinear MP)."""
    hw = HardwareModel()
    cfg = get_config(arch)
    for m in (2, 4, 8, 16):
        su = mp_step_speedup(cfg, m, hw)
        assert 1.0 <= su <= m, (arch, m, su)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_planner_best_dominates_dp_only(arch):
    """The planner's choice is never worse than any feasible DP-only point at
    the same budget (memory-infeasible DP points are *pruned*, so they are
    exempt from the dominance claim)."""
    cfg = get_config(arch)
    pl = HybridPlanner(cfg, epoch_model=default_epoch_model(cfg),
                       se_perfect=False)
    for d in (64, 512):
        choices = pl.choices(d)
        if not choices:      # arch does not fit at this budget at all
            continue
        best = choices[0]
        if any(c.mp_kind == "none" for c in choices):
            dp_only = speedup_hybrid(pl.run, d, 1)
            assert best.speedup >= dp_only - 1e-9


@pytest.mark.parametrize("arch", PLANNER_ARCHS)
def test_planner_choices_factorize_budget(arch):
    """Every returned choice factorizes the device budget exactly, and its
    executable plan is consistent with the choice's (kind, M, K)."""
    cfg, pl = make_planner(arch)
    for d in PLANNER_BUDGETS:
        for c in pl.choices(d):
            assert c.pods * c.dp * c.mp == d, (arch, d, c)
            prod = 1
            for s in c.mesh_shape:
                prod *= s
            assert prod == d, (arch, d, c.mesh_shape)
            assert (c.mp > 1) == (c.plan.model_axis is not None)
            if c.mp_kind == "pipeline":
                assert c.plan.mp_kind == "pipeline"
                assert c.plan.microbatches == c.microbatches > 1
                assert c.plan.schedule == c.schedule in (
                    "gpipe", "1f1b", "interleaved")
                assert c.plan.virtual_stages == c.virtual_stages
                assert (c.virtual_stages > 1) == (c.schedule == "interleaved")
                assert cfg.n_layers % (c.mp * c.virtual_stages) == 0, (arch, c)
            else:
                assert c.microbatches == 1
                assert c.schedule == "-" and c.virtual_stages == 1
                if c.mp_kind == "context":
                    assert c.plan.mp_kind == "context"
                    # ring sizes come from the sequence-divisibility-
                    # filtered cp table (ISSUE 8)
                    assert c.mp in pl.run.cp_speedup, (arch, c)
                else:
                    assert c.plan.mp_kind == "tensor"


@pytest.mark.parametrize("arch", PLANNER_ARCHS)
def test_planner_choices_sorted_by_speedup(arch):
    """choices() is best-first: projected speedups are non-increasing."""
    _, pl = make_planner(arch)
    for d in PLANNER_BUDGETS:
        sus = [c.speedup for c in pl.choices(d)]
        assert all(a >= b - 1e-12 for a, b in zip(sus, sus[1:])), (arch, d)


@pytest.mark.parametrize("arch", PLANNER_ARCHS)
def test_planner_memory_feasibility(arch):
    """No returned choice exceeds the per-device memory budget, fsdp is only
    engaged when the unsharded point does not fit, and infeasible pure-DP
    points never appear."""
    cfg, pl = make_planner(arch)
    hbm = pl.hw.hbm_bytes
    for d in PLANNER_BUDGETS:
        for c in pl.choices(d):
            assert c.mem_bytes <= hbm, (arch, d, c)
            mem_plain = per_device_mem_bytes(
                cfg, mp=c.mp,
                # context replicates params across the ring, so its
                # unsharded point is costed with its own memory model
                mp_kind=(c.mp_kind if c.mp_kind in ("pipeline", "context")
                         else "tensor"),
                fsdp=1, mini_batch=pl.mini_batch, seq_len=pl.seq_len,
                opt_bytes_per_param=pl.opt_bytes_per_param, remat=pl.remat,
                microbatches=c.microbatches,
                schedule=c.schedule if c.mp_kind == "pipeline" else "gpipe",
                virtual_stages=c.virtual_stages)
            if c.plan.fsdp_axes:
                assert mem_plain > hbm, (arch, d, c)     # fsdp was needed
            else:
                assert mem_plain <= hbm, (arch, d, c)    # and reported as such
            if c.mp_kind == "none" and not c.plan.fsdp_axes:
                assert mem_plain <= hbm


def test_planner_prunes_infeasible_pure_dp():
    """1T params on 16 GiB devices: unsharded pure DP must never be ranked."""
    cfg, pl = make_planner("kimi_k2_1t_a32b")
    assert per_device_mem_bytes(
        cfg, mp=1, fsdp=1, mini_batch=pl.mini_batch, seq_len=pl.seq_len,
        opt_bytes_per_param=pl.opt_bytes_per_param) > pl.hw.hbm_bytes
    for d in PLANNER_BUDGETS:
        for c in pl.choices(d):
            assert not (c.mp_kind == "none" and not c.plan.fsdp_axes), (d, c)


@pytest.mark.parametrize("arch", ["biglstm", "gnmt", "llama3_2_1b"])
def test_pipeline_step_speedup_monotone_in_micro(arch):
    """More micro-batches => smaller bubble => SU^M non-decreasing in K, and
    SU^M is always in (0, M]."""
    cfg = get_config(arch)
    hw = HardwareModel()
    for m in (2, 4):
        if cfg.n_layers % m:
            continue
        sus = [pipeline_step_speedup_model(cfg, m, k, hw, mini_batch=16,
                                           seq_len=4096)
               for k in (2, 4, 8, 16)]
        assert all(0.0 < su <= m for su in sus), (arch, m, sus)
        assert all(b >= a - 1e-12 for a, b in zip(sus, sus[1:])), (arch, m)


def test_speedup_pipeline_reduces_to_dp_at_m1():
    run = run_with(1024)
    for n in (4, 64):
        assert speedup_pipeline(run, n, 1, 8) == pytest.approx(
            speedup_dp(run, n))


@pytest.mark.parametrize("seed", range(6))
def test_list_schedule_lower_bounds(seed):
    """Any placement's makespan >= max(critical path, work/devices)."""
    rng = np.random.default_rng(200 + seed)
    n = int(rng.integers(5, 12))
    nodes = {f"n{i}": OpCost(float(rng.uniform(1e8, 1e9)), 1e4)
             for i in range(n)}
    edges = [(f"n{i}", f"n{j}") for i in range(n) for j in range(i + 1, n)
             if rng.random() < 0.3]
    dfg = DFG(nodes, edges)
    hw = HardwareGraph(n_devices=2)
    placement = {k: int(rng.integers(0, 2)) for k in nodes}
    ms = list_schedule(dfg, hw, placement)
    work = sum(c.flops for c in nodes.values()) / hw.flops_per_s
    assert ms >= work / 2 - 1e-9


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape", list(INPUT_SHAPES))
def test_model_flops_positive_and_scaling(arch, shape):
    cfg = get_config(arch)
    f = model_flops(cfg, INPUT_SHAPES[shape])
    assert f > 0
    if shape == "train_4k":
        # at least 6 * active params * tokens
        assert f >= 6 * cfg.n_active_params() * 4096 * 256 * 0.99


def test_fig3_benchmark_claims():
    from benchmarks.fig3_example import run
    assert run()
