"""End-to-end behaviour tests: planner -> plan -> training run; serving
engine correctness; data pipeline; roofline parsing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.planner import HybridPlanner, default_epoch_model
from repro.core.roofline import (Roofline, model_flops, parse_collectives)
from repro.configs.base import INPUT_SHAPES
from repro.data import DataPipeline, make_lm_dataset
from repro.models import build_model
from repro.optim import adamw, warmup_cosine
from repro.serve.engine import ServeEngine
from repro.train.loop import LoopConfig, train_loop
from repro.train.steps import init_train_state, make_train_step


def test_planner_emits_executable_plans():
    cfg = get_config("llama3_2_1b")
    planner = HybridPlanner(cfg, epoch_model=default_epoch_model(cfg),
                            se_perfect=False)
    for devices in (16, 256, 512):
        choice = planner.best(devices)
        assert choice.dp * choice.mp * choice.pods == devices
        assert choice.speedup > 1
        # mesh shape must multiply out to the budget
        n = 1
        for s in choice.mesh_shape:
            n *= s
        assert n == devices


def test_planner_prefers_mp_at_scale():
    """Past the statistical-efficiency cliff the planner must pick MP > 1 —
    the paper's central claim."""
    cfg = get_config("llama3_2_1b")
    planner = HybridPlanner(cfg, epoch_model=default_epoch_model(cfg),
                            se_perfect=False)
    small = planner.best(8)
    big = planner.best(2048)
    assert small.mp <= big.mp
    assert big.mp > 1


def test_planner_crossover_finite():
    cfg = get_config("llama3_2_1b")
    planner = HybridPlanner(cfg, epoch_model=default_epoch_model(cfg))
    x = planner.crossover(m=2)
    assert x is not None and x >= 2


def test_end_to_end_training_converges_toward_floor():
    cfg = get_config("llama3_2_1b").reduced()
    api = build_model(cfg)
    data = make_lm_dataset(vocab=64, seq_len=32, n_items=1024)
    opt = adamw(warmup_cosine(5e-3, 5, 60))
    step = jax.jit(make_train_step(api, opt), donate_argnums=(0,))
    state = init_train_state(api, opt, jax.random.PRNGKey(0))

    pipeline = DataPipeline(lambda e: ({"tokens": jnp.asarray(b["tokens"]),
                                        "labels": jnp.asarray(b["labels"])}
                                       for b in data.epoch(e, 32)))
    res = train_loop(step, state, pipeline,
                     LoopConfig(total_steps=60, log_every=1000),
                     log_fn=lambda s: None)
    hist = res["history"]
    assert np.mean(hist[-5:]) < np.mean(hist[:5]) - 0.5


def test_serve_greedy_matches_teacher_forcing():
    """Greedy generation then teacher-forcing the generated tokens must
    reproduce the same argmax chain."""
    cfg = get_config("llama3_2_1b").reduced()
    api = build_model(cfg, remat=False)
    key = jax.random.PRNGKey(0)
    params = api.init(key)
    engine = ServeEngine(api, params)
    prompt = {"tokens": jax.random.randint(key, (2, 8), 0, cfg.vocab_size,
                                           dtype=jnp.int32)}
    res = engine.generate(prompt, max_new_tokens=4)
    # teacher-force: feed prompt + generated, check argmax at each position
    from repro.models import transformer as tf_mod
    full = jnp.concatenate([prompt["tokens"], res.tokens], axis=1)
    logits, _ = tf_mod.forward(cfg, params, {"tokens": full}, mode="train",
                               remat=False)
    for i in range(4):
        pos = 8 + i - 1
        pred = jnp.argmax(logits[:, pos], -1)
        np.testing.assert_array_equal(np.asarray(pred),
                                      np.asarray(res.tokens[:, i]))


def test_markov_dataset_properties():
    d = make_lm_dataset(vocab=32, seq_len=16, n_items=256)
    assert 0 < d.entropy < np.log(32)
    b1 = list(d.epoch(0, 64))
    b2 = list(d.epoch(0, 64))
    np.testing.assert_array_equal(b1[0]["tokens"], b2[0]["tokens"])  # determinism
    b3 = list(d.epoch(1, 64))
    assert not np.array_equal(b1[0]["tokens"], b3[0]["tokens"])  # reshuffled
    assert b1[0]["tokens"].shape == (64, 16)
    # labels are next-token shifted
    np.testing.assert_array_equal(b1[0]["tokens"][:, 1:], b1[0]["labels"][:, :-1])


def test_collective_parser():
    hlo = """
  ENTRY %main {
    %ar = bf16[128,256] all-reduce(bf16[128,256] %x), replica_groups=[16,16]<=[256]
    %ag = f32[64]{0} all-gather(f32[4] %y), replica_groups={{0,1,2,3}}
    %cp = bf16[32,32] collective-permute(bf16[32,32] %z)
  }
    """
    st = parse_collectives(hlo, default_group=256)
    assert st.ops == {"all-reduce": 1, "all-gather": 1, "collective-permute": 1}
    ar_bytes = 128 * 256 * 2
    assert st.wire_bytes == pytest.approx(
        ar_bytes * 2 * 15 / 16 + 64 * 4 * 3 / 4 + 32 * 32 * 2)


def test_roofline_terms():
    r = Roofline(chips=256, hlo_flops_per_chip=197e12,
                 hlo_bytes_per_chip=819e9,
                 collective_wire_bytes_per_chip=200e9,
                 model_flops_total=197e12 * 256 * 0.5)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(1.0)
    assert r.t_collective == pytest.approx(1.0)
    assert r.useful_flops_ratio == pytest.approx(0.5)
    assert r.mfu == pytest.approx(0.5)


def test_model_flops_kinds():
    cfg = get_config("llama3_2_1b")
    tr = model_flops(cfg, INPUT_SHAPES["train_4k"])
    pf = model_flops(cfg, INPUT_SHAPES["prefill_32k"])
    dc = model_flops(cfg, INPUT_SHAPES["decode_32k"])
    assert tr > pf > dc
    # per-token, train (fwd+bwd, 4k ctx) costs ~2-3.5x prefill (fwd, 32k ctx):
    # the 3x fwd/bwd factor minus prefill's larger quadratic-attention share
    tokens_tr = 4096 * 256
    tokens_pf = 32768 * 32
    ratio = (tr / tokens_tr) / (pf / tokens_pf)
    assert 1.5 <= ratio <= 3.5, ratio
