"""Per-kernel validation: shape/dtype sweeps, interpret=True vs the pure-jnp
oracle in ref.py (the deliverable-c kernel test requirement)."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import flash_attention as FA
from repro.kernels import lstm_cell as LC
from repro.kernels import moe_gmm as GM
from repro.kernels import ref as R
from repro.kernels import rwkv_scan as WK


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32) * 0.5
    return x.astype(dtype)


@pytest.mark.parametrize("b,t,h,hd", [(2, 256, 4, 64), (1, 128, 2, 128),
                                      (1, 192, 3, 64), (2, 96, 5, 32)])
@pytest.mark.slow
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
def test_flash_attention_sweep(b, t, h, hd, dtype, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(b * t + h), 3)
    q = _rand(ks[0], (b, t, h, hd), dtype)
    k = _rand(ks[1], (b, t, h, hd), dtype)
    v = _rand(ks[2], (b, t, h, hd), dtype)
    out = FA.flash_attention(q, k, v, causal=causal, window=window,
                             block_q=64, block_k=64, interpret=True)
    ref = R.attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    assert float(jnp.abs(out.astype(jnp.float32)
                         - ref.astype(jnp.float32)).max()) < tol


@pytest.mark.parametrize("b,t,h,hd", [(1, 70, 2, 32),    # t % block != 0
                                      (2, 130, 2, 32),   # one partial tail
                                      (1, 7, 2, 32),     # tq < 16 (min bq)
                                      (1, 1, 2, 32)])    # single row
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 3), (False, 0)])
def test_flash_attention_edge_shapes(b, t, h, hd, causal, window):
    """ISSUE 8 satellite: non-block-multiple sequence lengths, tiny tq below
    the 16-row minimum block, and window+causal combined — the padded tail
    rows/cols must be masked out, not attended."""
    ks = jax.random.split(jax.random.PRNGKey(t * 7 + window), 3)
    q = _rand(ks[0], (b, t, h, hd), jnp.float32)
    k = _rand(ks[1], (b, t, h, hd), jnp.float32)
    v = _rand(ks[2], (b, t, h, hd), jnp.float32)
    out = FA.flash_attention(q, k, v, causal=causal, window=window,
                             block_q=64, block_k=64, interpret=True)
    ref = R.attention_ref(q, k, v, causal=causal, window=window)
    assert out.shape == ref.shape
    err = float(jnp.abs(out - ref).max())
    assert err < 2e-5, (b, t, causal, window, err)


def test_flash_attention_cross_lengths():
    """Tq != Tk (non-causal cross attention)."""
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = _rand(ks[0], (2, 100, 2, 64), jnp.float32)
    k = _rand(ks[1], (2, 260, 2, 64), jnp.float32)
    v = _rand(ks[2], (2, 260, 2, 64), jnp.float32)
    out = FA.flash_attention(q, k, v, causal=False, block_q=64, block_k=64,
                             interpret=True)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / 8.0
    p = jax.nn.softmax(s, -1)
    ref = jnp.einsum("bhqk,bkhd->bqhd", p, v)
    assert float(jnp.abs(out - ref).max()) < 2e-5


@pytest.mark.slow
@pytest.mark.parametrize("t,chunk", [(128, 32), (256, 64), (256, 128)])
@pytest.mark.parametrize("hd", [32, 64])
def test_wkv6_sweep(t, chunk, hd):
    b, h = 2, 3
    ks = jax.random.split(jax.random.PRNGKey(t + hd), 5)
    r = _rand(ks[0], (b, t, h, hd), jnp.float32)
    k = _rand(ks[1], (b, t, h, hd), jnp.float32)
    v = _rand(ks[2], (b, t, h, hd), jnp.float32)
    w = jnp.exp(-jnp.exp(_rand(ks[3], (b, t, h, hd), jnp.float32) - 2))
    u = _rand(ks[4], (h, hd), jnp.float32) * 0.2
    out = WK.wkv6(r, k, v, w, u, chunk=chunk, interpret=True)
    ref, _ = R.wkv6_ref(r, k, v, w, u)
    assert float(jnp.abs(out - ref).max()) < 2e-4


@pytest.mark.parametrize("g,c,d,f", [(4, 100, 192, 160), (2, 64, 64, 64),
                                     (8, 37, 130, 70)])
@pytest.mark.slow
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gmm_sweep(g, c, d, f, dtype):
    ks = jax.random.split(jax.random.PRNGKey(g * c), 2)
    x = _rand(ks[0], (g, c, d), dtype)
    w = _rand(ks[1], (g, d, f), dtype) * 0.2
    out = GM.gmm(x, w, block_c=64, block_f=64, block_d=64, interpret=True)
    ref = R.gmm_ref(x, w)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    assert float(jnp.abs(out.astype(jnp.float32)
                         - ref.astype(jnp.float32)).max()) < tol


@pytest.mark.slow
@pytest.mark.parametrize("bsz,din,hh", [(36, 96, 200), (8, 64, 64),
                                        (130, 128, 96)])
def test_lstm_cell_sweep(bsz, din, hh):
    ks = jax.random.split(jax.random.PRNGKey(bsz), 6)
    x = _rand(ks[0], (bsz, din), jnp.float32)
    h = _rand(ks[1], (bsz, hh), jnp.float32)
    c = _rand(ks[2], (bsz, hh), jnp.float32)
    wx = _rand(ks[3], (din, 4, hh), jnp.float32) * 0.2
    wh = _rand(ks[4], (hh, 4, hh), jnp.float32) * 0.2
    b = jnp.zeros((4, hh))
    hn, cn = LC.lstm_cell(x, h, c, wx, wh, b, block_b=32, block_h=64,
                          interpret=True)
    hr, cr = R.lstm_cell_ref(x, h, c, wx, wh, b)
    assert float(jnp.abs(hn - hr).max()) < 1e-5
    assert float(jnp.abs(cn - cr).max()) < 1e-5


def test_ops_dispatch_cpu_uses_ref():
    from repro.kernels import ops
    assert not ops.use_pallas()  # CPU container
    q = _rand(jax.random.PRNGKey(0), (1, 32, 2, 16), jnp.float32)
    out = ops.attention(q, q, q, causal=True)
    ref = R.attention_ref(q, q, q, causal=True)
    assert float(jnp.abs(out - ref).max()) < 1e-5
