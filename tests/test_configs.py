"""Config registry: every assigned arch loads with the exact assigned
dimensions, reduced variants are valid, param counts are in the right range."""
import pytest

from repro.configs import ARCH_IDS, INPUT_SHAPES, PAPER_IDS, get_config

ASSIGNED = {
    # arch: (layers, d_model, heads, kv, d_ff, vocab)
    "internvl2_2b": (24, 2048, 16, 8, 8192, 92553),
    "granite_moe_1b_a400m": (24, 1024, 16, 8, 512, 49155),
    "kimi_k2_1t_a32b": (61, 7168, 64, 8, 2048, 163840),
    "stablelm_12b": (40, 5120, 32, 8, 13824, 100352),
    "smollm_360m": (32, 960, 15, 5, 2560, 49152),
    "llama3_2_1b": (16, 2048, 32, 8, 8192, 128256),
    "hymba_1_5b": (32, 1600, 25, 5, 5504, 32001),
    "rwkv6_7b": (32, 4096, 0, 0, 14336, 65536),
    "nemotron_4_340b": (96, 18432, 96, 8, 73728, 256000),
    "whisper_large_v3": (32, 1280, 20, 20, 5120, 51866),
}

PARAM_RANGES = {  # billions (total)
    "internvl2_2b": (1.2, 2.5), "granite_moe_1b_a400m": (0.8, 1.8),
    "kimi_k2_1t_a32b": (900, 1200), "stablelm_12b": (10, 14),
    "smollm_360m": (0.25, 0.5), "llama3_2_1b": (1.0, 1.8),
    "hymba_1_5b": (1.1, 2.0), "rwkv6_7b": (6, 9),
    "nemotron_4_340b": (300, 380), "whisper_large_v3": (1.2, 2.2),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_assigned_dims(arch):
    cfg = get_config(arch)
    layers, d, h, kv, ff, v = ASSIGNED[arch]
    assert cfg.n_layers == layers
    assert cfg.d_model == d
    assert cfg.n_heads == h
    assert cfg.n_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == v
    assert cfg.source, "every config must cite its source"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_range(arch):
    n = get_config(arch).n_params() / 1e9
    lo, hi = PARAM_RANGES[arch]
    assert lo <= n <= hi, f"{arch}: {n:.2f}B not in [{lo}, {hi}]"


def test_moe_active_params():
    kimi = get_config("kimi_k2_1t_a32b")
    active = kimi.n_active_params() / 1e9
    assert 25 <= active <= 40, active  # "a32b"
    granite = get_config("granite_moe_1b_a400m")
    assert 0.3 <= granite.n_active_params() / 1e9 <= 0.6


@pytest.mark.parametrize("arch", ARCH_IDS + PAPER_IDS)
def test_reduced_variants(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers <= 2 or cfg.family == "cnn"
    assert cfg.d_model <= 512
    if cfg.is_moe:
        assert cfg.n_experts <= 4
    if cfg.n_heads:
        assert cfg.n_heads % cfg.n_kv_heads == 0


def test_input_shapes():
    assert INPUT_SHAPES["train_4k"].seq_len == 4096
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["prefill_32k"].seq_len == 32768
    assert INPUT_SHAPES["prefill_32k"].global_batch == 32
    assert INPUT_SHAPES["decode_32k"].global_batch == 128
    assert INPUT_SHAPES["long_500k"].seq_len == 524288
    assert INPUT_SHAPES["long_500k"].global_batch == 1


def test_vocab_padding_divisible():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        assert cfg.vocab_padded % 256 == 0
        assert cfg.vocab_padded >= cfg.vocab_size
