"""PipelineSchedule invariants: every emitted table must be a *valid*
schedule (dependency-respecting, one unit per (tick, stage) cell, complete),
and the closed-form bubble/residency analytics the planner consumes must
agree with what the tables actually realize."""
import numpy as np
import pytest

from repro.parallel.pipeline import (PipelineSchedule, SCHEDULE_KINDS,
                                     make_schedule,
                                     pipeline_activation_residency,
                                     pipeline_bubble_fraction,
                                     pipeline_step_speedup)

GRID = [(S, K) for S in (2, 4) for K in (2, 4, 8)]


def _check_full_table(sched):
    """Validates table(): unique cells, complete, deps respected with the
    one-tick ppermute arrival delay.  Returns total ticks."""
    S, K, V = sched.n_stages, sched.n_micro, sched.n_virtual
    cells = set()
    fdone, bdone = {}, {}
    for u in sched.table():
        assert (u.tick, u.stage) not in cells, (sched, u)
        cells.add((u.tick, u.stage))
        j = u.chunk * S + u.stage
        assert j % S == u.stage
        if u.direction == "fwd":
            if j > 0:
                assert fdone[(u.micro, j - 1)] < u.tick, (sched.kind, u)
            fdone[(u.micro, j)] = u.tick
        else:
            if j == V - 1:
                assert fdone[(u.micro, j)] < u.tick, (sched.kind, u)
            else:
                assert bdone[(u.micro, j + 1)] < u.tick, (sched.kind, u)
            bdone[(u.micro, j)] = u.tick
    assert len(fdone) == len(bdone) == K * V, sched
    return max(t for t, _ in cells) + 1


@pytest.mark.parametrize("kind", SCHEDULE_KINDS)
@pytest.mark.parametrize("S,K", GRID)
def test_table_valid_and_total_ticks(kind, S, K):
    sched = make_schedule(kind, S, K)
    T = _check_full_table(sched)
    if kind in ("gpipe", "1f1b"):
        # both realize the classic 2*(K+S-1) span with tf = tb = 1
        assert T == 2 * (K + S - 1), (kind, S, K, T)


@pytest.mark.parametrize("kind", SCHEDULE_KINDS)
@pytest.mark.parametrize("S,K", GRID)
def test_closed_form_analytics_match_table(kind, S, K):
    """bubble_fraction() / activation_residency() are the closed forms the
    planner evaluates in its search loop; they must equal what the emitted
    table realizes."""
    sched = make_schedule(kind, S, K)
    assert sched.residency_from_table() == pytest.approx(
        sched.activation_residency()), (kind, S, K)
    tbl = sched.table()
    busy = len(tbl) / sched.n_stages
    total = tbl[-1].tick + 1
    derived = 1.0 - busy / total
    if kind in ("gpipe", "1f1b"):
        assert sched.bubble_fraction() == pytest.approx((S - 1) / (K + S - 1))
        assert derived == pytest.approx(sched.bubble_fraction())
    else:
        # interleaved tables pay warmup/drain on top of the steady-state
        # closed form; at the packed wave (S | K) the forward halves match
        assert derived >= sched.bubble_fraction() - 1e-9
        if K % S == 0:
            v = sched.v
            assert sched.bubble_fraction() == pytest.approx(
                (S - 1) / (v * K + S - 1))


@pytest.mark.parametrize("S,K", GRID)
def test_forward_table_wavefront_consistency(S, K):
    """The executor's correctness invariant: a non-injected unit's input is
    exactly what its ring-left neighbour produced one tick earlier."""
    for kind in SCHEDULE_KINDS:
        sched = make_schedule(kind, S, K)
        tbl = sched.forward_table()
        micro, chunk = tbl["micro"], tbl["chunk"]
        inject = tbl["inject"]
        T = micro.shape[0]
        for t in range(T):
            for s in range(S):
                if micro[t, s] < 0 or inject[t, s]:
                    continue
                left = (s - 1) % S
                j = chunk[t, s] * S + s
                assert t > 0 and micro[t - 1, left] == micro[t, s], \
                    (kind, t, s)
                assert chunk[t - 1, left] * S + left == j - 1, (kind, t, s)


def test_residency_ordering():
    """1f1b <= gpipe at every (S, K); interleaved within (1f1b, gpipe]."""
    for S, K in GRID:
        g = pipeline_activation_residency(K, S, "gpipe")
        f = pipeline_activation_residency(K, S, "1f1b")
        i = pipeline_activation_residency(K, S, "interleaved", 2)
        assert f <= g and f <= i <= max(g, f + S), (S, K, g, f, i)
        assert g == K and f == min(K, S)


def test_bubble_ordering_and_speedup():
    """interleaved < gpipe == 1f1b bubbles at the packed wave; more micros
    monotonically shrink every schedule's bubble."""
    for S in (2, 4):
        for K in (S, 2 * S, 4 * S):
            bg = pipeline_bubble_fraction(K, S, "gpipe")
            bf = pipeline_bubble_fraction(K, S, "1f1b")
            bi = pipeline_bubble_fraction(K, S, "interleaved", 2)
            assert bg == bf
            assert bi < bg, (S, K, bi, bg)
        bs = [pipeline_bubble_fraction(K, S, "1f1b") for K in (2, 4, 8, 16)]
        assert all(b > a for a, b in zip(bs, bs[1:])) is False  # decreasing
        assert all(a >= b for a, b in zip(bs, bs[1:]))
    assert pipeline_step_speedup(4, 8, 0.0, "interleaved", 2) > \
        pipeline_step_speedup(4, 8, 0.0, "gpipe")


def test_schedule_validation():
    with pytest.raises(ValueError):
        PipelineSchedule("gpipe", 2, 4, n_virtual_per_stage=2)
    with pytest.raises(ValueError):
        PipelineSchedule("interleaved", 2, 4, n_virtual_per_stage=1)
    with pytest.raises(ValueError):
        PipelineSchedule("bogus", 2, 4)
    # make_schedule normalizes v
    assert make_schedule("1f1b", 2, 4, 2).v == 1
    assert make_schedule("interleaved", 2, 4).v == 2


def test_describe_mentions_bubble():
    s = make_schedule("interleaved", 4, 8, 2)
    d = s.describe()
    assert "interleaved" in d and "bubble" in d and "v=2" in d
