"""Model zoo tests: per-arch smoke (reduced config, one train step, shapes +
no NaNs — the required deliverable-f tests) and the serving-correctness
property: cached decode == teacher-forced forward for every family."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, INPUT_SHAPES, PAPER_IDS, get_config
from repro.models import build_model
from repro.models import transformer as tf_mod
from repro.optim import adamw, constant_lr
from repro.train.steps import init_train_state, make_train_step


@pytest.mark.parametrize("arch", ARCH_IDS + PAPER_IDS)
def test_smoke_forward(arch):
    """Reduced variant: one forward pass on CPU; output shapes + finite."""
    cfg = get_config(arch).reduced()
    api = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init(key)
    batch = api.make_batch(key, INPUT_SHAPES["train_4k"])
    loss, metrics = jax.jit(lambda p, b: api.loss_fn(p, b))(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: loss={loss}"
    assert 0 < float(loss) < 20


@pytest.mark.parametrize("arch", ["llama3_2_1b", "granite_moe_1b_a400m",
                                  "rwkv6_7b", "hymba_1_5b"])
def test_smoke_train_step(arch):
    """One full optimizer step: params change, loss finite."""
    cfg = get_config(arch).reduced()
    api = build_model(cfg)
    opt = adamw(constant_lr(1e-3))
    step = jax.jit(make_train_step(api, opt))
    key = jax.random.PRNGKey(0)
    state = init_train_state(api, opt, key)
    batch = api.make_batch(key, INPUT_SHAPES["train_4k"])
    new_state, metrics = step(state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert int(new_state.step) == 1
    # params must actually move
    before = jax.tree.leaves(state.params)[0]
    after = jax.tree.leaves(new_state.params)[0]
    assert not jnp.allclose(before, after)


DECODE_ARCHS = ["llama3_2_1b", "smollm_360m", "hymba_1_5b", "rwkv6_7b",
                "granite_moe_1b_a400m", "whisper_large_v3", "internvl2_2b",
                "kimi_k2_1t_a32b", "stablelm_12b", "nemotron_4_340b"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_forward(arch):
    """Prefill T-3 tokens then decode 3 — logits must match the full forward
    (no-drop MoE capacity)."""
    T = 12
    cfg = get_config(arch).reduced()
    api = build_model(cfg, remat=False, capacity_factor=None)
    key = jax.random.PRNGKey(1)
    params = api.init(key)
    batch = {"tokens": jax.random.randint(key, (2, T), 0, cfg.vocab_size,
                                          dtype=jnp.int32)}
    if cfg.n_prefix_embeds:
        batch["prefix"] = jax.random.normal(
            key, (2, 8, cfg.d_model), dtype=jnp.float32) * 0.02
    if cfg.encoder_layers:
        batch["frames"] = jax.random.normal(
            key, (2, cfg.encoder_seq, cfg.d_model), dtype=jnp.float32) * 0.02
    ref, _ = tf_mod.forward(cfg, params, batch, mode="train", remat=False,
                            capacity_factor=None)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :T - 3]
    logits, cache = api.prefill(params, pre, capacity=T + 8)
    errs = [float(jnp.abs(logits[:, -1] - ref[:, T - 4]).max())]
    for t in range(T - 3, T):
        step = {"tokens": batch["tokens"][:, t:t + 1]}
        logits, cache = api.decode_fn(params, cache, step)
        errs.append(float(jnp.abs(logits[:, 0] - ref[:, t]).max()))
    assert max(errs) < 1e-4, f"{arch}: {errs}"


def test_sliding_window_decode_matches_forward():
    """Window smaller than sequence: ring cache must agree with windowed
    teacher-forcing."""
    import dataclasses
    T, W = 20, 8
    cfg = dataclasses.replace(get_config("llama3_2_1b").reduced(),
                              sliding_window=W)
    api = build_model(cfg, remat=False)
    key = jax.random.PRNGKey(3)
    params = api.init(key)
    tokens = jax.random.randint(key, (2, T), 0, cfg.vocab_size, dtype=jnp.int32)
    ref, _ = tf_mod.forward(cfg, params, {"tokens": tokens}, mode="train",
                            remat=False)
    logits, cache = api.prefill(params, {"tokens": tokens[:, :T - 4]},
                                capacity=T)
    errs = [float(jnp.abs(logits[:, -1] - ref[:, T - 5]).max())]
    for t in range(T - 4, T):
        logits, cache = api.decode_fn(params, cache, {"tokens": tokens[:, t:t + 1]})
        errs.append(float(jnp.abs(logits[:, 0] - ref[:, t]).max()))
    assert max(errs) < 1e-4, errs


def test_moe_dispatch_matches_dense_oracle():
    from repro.models import moe as M
    cfg = get_config("granite_moe_1b_a400m").reduced()
    key = jax.random.PRNGKey(0)
    params = M.moe_init(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 24, cfg.d_model)) * 0.5
    out, _ = M.moe_ffn(params, x, cfg, capacity_factor=None)
    ref, _ = M.moe_ffn_dense_oracle(params, x, cfg)
    assert float(jnp.abs(out - ref).max()) < 1e-5


@pytest.mark.parametrize("seed", range(3))
def test_moe_capacity_drops_bounded(seed):
    """With cf=1.0 the dispatch drops tokens but output stays finite and
    close-ish to the no-drop output (property over seeds)."""
    from repro.models import moe as M
    cfg = get_config("granite_moe_1b_a400m").reduced()
    params = M.moe_init(jax.random.PRNGKey(seed), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 100), (2, 32, cfg.d_model))
    out, aux = M.moe_ffn(params, x, cfg, capacity_factor=1.0)
    assert jnp.isfinite(out).all()
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("t,chunk", [(128, 32), (128, 64), (256, 64)])
def test_rwkv_chunked_equals_scan(t, chunk):
    from repro.models import rwkv as R
    key = jax.random.PRNGKey(0)
    b, h, hd = 2, 3, 16
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (b, t, h, hd)) * 0.5
    k = jax.random.normal(ks[1], (b, t, h, hd)) * 0.5
    v = jax.random.normal(ks[2], (b, t, h, hd)) * 0.5
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (b, t, h, hd)) * 0.5 - 2))
    u = jax.random.normal(ks[4], (h, hd)) * 0.1
    o1, s1 = R.wkv_scan(r, k, v, w, u)
    o2, s2 = R.wkv_chunked(r, k, v, w, u, chunk=chunk)
    assert float(jnp.abs(o1 - o2).max()) < 1e-4
    assert float(jnp.abs(s1 - s2).max()) < 1e-4


def test_vocab_padding_masks_logits():
    cfg = get_config("hymba_1_5b").reduced()  # vocab 1024 already padded?
    import dataclasses
    cfg = dataclasses.replace(cfg, vocab_size=1000)  # force padding
    api = build_model(cfg, remat=False)
    params = api.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((1, 8), jnp.int32)}
    logits, _ = tf_mod.forward(cfg, params, batch, mode="train", remat=False)
    assert logits.shape[-1] == cfg.vocab_padded
    assert float(logits[..., cfg.vocab_size:].max()) < -1e20


def test_gnmt_and_biglstm_shapes():
    for arch in ["gnmt", "biglstm"]:
        cfg = get_config(arch).reduced()
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        batch = api.make_batch(jax.random.PRNGKey(1), INPUT_SHAPES["train_4k"])
        loss, _ = api.loss_fn(params, batch)
        assert jnp.isfinite(loss)


def test_inception_dfg_exports():
    from repro.models.inception import inception_dfg
    nodes, edges = inception_dfg()
    import networkx as nx
    g = nx.DiGraph(edges)
    assert nx.is_directed_acyclic_graph(g)
    assert len(nodes) > 40  # 11 blocks x branches + stem/head/concats
    # parallel branches exist: some node has >= 3 successors
    assert max(dict(g.out_degree()).values()) >= 3
    assert all(n["flops"] >= 0 for n in nodes.values())
