"""Training substrate invariants: gradient accumulation == large batch (the
paper's §4.2 emulation must be exact), optimizer math, checkpoint roundtrip,
and end-to-end loss descent."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (latest_checkpoint, restore_checkpoint,
                              save_checkpoint)
from repro.configs import INPUT_SHAPES, get_config
from repro.data import make_lm_dataset
from repro.models import build_model
from repro.optim import (adafactor, adamw, apply_updates, constant_lr,
                         momentum_sgd, sgd)
from repro.optim.schedules import (exp_warmup_step_decay, linear_scaled_lr,
                                   warmup_cosine)
from repro.parallel.plan import ParallelPlan
from repro.train.steps import init_train_state, make_train_step


def test_grad_accum_equals_large_batch():
    """Delayed gradient update (paper §4.2): accumulating A micro-batches
    must produce the same update as one A-times-larger batch (with mean-loss
    semantics, plain SGD, no clipping)."""
    cfg = get_config("llama3_2_1b").reduced()
    api = build_model(cfg)
    opt = sgd(constant_lr(0.1))
    key = jax.random.PRNGKey(0)
    state_a = init_train_state(api, opt, key)
    state_b = init_train_state(api, opt, key)
    batch = api.make_batch(key, INPUT_SHAPES["train_4k"])  # (4, 128)

    step_full = jax.jit(make_train_step(api, opt, clip_norm=0.0,
                                        plan=ParallelPlan(microbatches=1)))
    step_accum = jax.jit(make_train_step(api, opt, clip_norm=0.0,
                                         plan=ParallelPlan(microbatches=4)))
    sa, _ = step_full(state_a, batch)
    sb, _ = step_accum(state_b, batch)
    for pa, pb in zip(jax.tree.leaves(sa.params), jax.tree.leaves(sb.params)):
        np.testing.assert_allclose(np.asarray(pa), np.asarray(pb),
                                   rtol=2e-4, atol=2e-5)


def test_adamw_matches_reference_math():
    opt = adamw(constant_lr(0.1), b1=0.9, b2=0.99, eps=1e-8)
    params = {"w": jnp.array([1.0, -2.0])}
    grads = {"w": jnp.array([0.5, 0.5])}
    state = opt.init(params)
    upd, state = opt.update(grads, state, params, jnp.zeros((), jnp.int32))
    m = 0.1 * 0.5
    v = 0.01 * 0.25
    expect = -0.1 * (m / 0.1) / (np.sqrt(v / 0.01) + 1e-8)
    np.testing.assert_allclose(np.asarray(upd["w"]),
                               [expect, expect], rtol=1e-5)


def test_momentum_sgd_accumulates():
    opt = momentum_sgd(constant_lr(1.0), momentum=0.5)
    params = {"w": jnp.zeros(2)}
    g = {"w": jnp.ones(2)}
    state = opt.init(params)
    u1, state = opt.update(g, state, params, jnp.zeros((), jnp.int32))
    u2, state = opt.update(g, state, params, jnp.ones((), jnp.int32))
    np.testing.assert_allclose(np.asarray(u1["w"]), [-1.0, -1.0])
    np.testing.assert_allclose(np.asarray(u2["w"]), [-1.5, -1.5])


def test_adafactor_state_is_factored():
    opt = adafactor(constant_lr(1e-2))
    params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((64,))}
    state = opt.init(params)
    acc = state["acc"]
    assert acc["w"]["vr"].shape == (64,)
    assert acc["w"]["vc"].shape == (32,)
    assert acc["b"]["v"].shape == (64,)
    g = {"w": jnp.ones((64, 32)), "b": jnp.ones((64,))}
    upd, state = opt.update(g, state, params, jnp.zeros((), jnp.int32))
    assert all(jnp.isfinite(x).all() for x in jax.tree.leaves(upd))


def test_schedules():
    lin = linear_scaled_lr(0.1, 256, 1024, warmup_steps=10)
    assert float(lin(100)) == pytest.approx(0.4)       # 4x batch => 4x LR
    assert float(lin(0)) < 0.41 / 10 + 1e-6            # warmup
    gnmt = exp_warmup_step_decay(1.0, warmup_steps=200, decay_start=6000,
                                 decay_interval=500, n_decays=4)
    assert float(gnmt(210)) == pytest.approx(1.0)
    assert float(gnmt(6000)) == pytest.approx(0.5)
    assert float(gnmt(6500)) == pytest.approx(0.25)
    assert float(gnmt(20000)) == pytest.approx(1.0 / 16)
    wc = warmup_cosine(1.0, 10, 100)
    assert float(wc(5)) < 1.0
    assert float(wc(99)) < float(wc(50))


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("smollm_360m").reduced()
    api = build_model(cfg)
    opt = adamw(constant_lr(1e-3))
    state = init_train_state(api, opt, jax.random.PRNGKey(0))
    f = save_checkpoint(str(tmp_path), state, 7)
    assert latest_checkpoint(str(tmp_path)) == f
    like = jax.tree.map(np.zeros_like, jax.device_get(state))
    restored = restore_checkpoint(f, like)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pipeline_auto_plan_trains_end_to_end():
    """Planner -> runtime integration: ``--parallel auto`` on biglstm must
    arg-max to a ``mp_kind="pipeline"`` plan (the paper's §4.4 MP for the
    RNNs) and train 3 steps through ``pipeline_apply`` on a forced
    **dp x stages** host mesh with dp > 1 — the hybrid DP x pipeline-MP
    execution the paper's thesis needs (DP no longer collapses to 1).
    Runs the real CLI in a subprocess so the forced device count does not
    leak into this pytest process."""
    import os
    import re
    import subprocess
    import sys

    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"),
               JAX_PLATFORMS="cpu")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "biglstm",
         "--parallel", "auto", "--reduced", "--steps", "3",
         "--batch", "8", "--seq", "16"],
        capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "kind=pipeline" in r.stdout, r.stdout      # planner chose pipeline
    assert "pipeline MP" in r.stdout, r.stdout        # runtime executed it
    m = re.search(r"\[plan\] (\d+)-way DP x (\d+)-way pipeline MP", r.stdout)
    assert m, r.stdout                                # executed-plan banner
    assert int(m.group(1)) > 1, r.stdout              # real DP, dp x stages
    assert int(m.group(2)) > 1, r.stdout
    assert "final_loss=" in r.stdout, r.stdout        # 3 steps completed
    loss = float(r.stdout.split("final_loss=")[1].split()[0])
    assert np.isfinite(loss), loss


def test_loss_descends_on_markov_task():
    """End-to-end: 40 steps on the synthetic task must cut the gap to the
    entropy floor meaningfully."""
    cfg = dataclasses.replace(get_config("smollm_360m").reduced(),
                              n_layers=2, vocab_size=64)
    api = build_model(cfg)
    data = make_lm_dataset(vocab=64, seq_len=32, n_items=2048)
    opt = adamw(warmup_cosine(5e-3, 5, 40))
    step = jax.jit(make_train_step(api, opt))
    state = init_train_state(api, opt, jax.random.PRNGKey(0))
    losses = []
    it = data.epoch(0, 32)
    for i, batch in enumerate(it):
        if i >= 40:
            break
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    first, last = losses[0], np.mean(losses[-5:])
    floor = data.entropy
    assert last < first - 0.3 * (first - floor), (first, last, floor)
