import os
import sys

# tests run single-device (the dry-run alone forces 512 host devices)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
