"""The paper's analytical framework: Eq. 3/5/6 identities, crossover behavior
reproducing the paper's claims, epoch-model fits, and DLPlacer optimality."""
import math

import numpy as np
import pytest

from repro.core.analytical import (TrainingRun, best_strategy,
                                   crossover_device_count, hybrid_wins,
                                   speedup_dp, speedup_hybrid)
from repro.core.comm import (HardwareModel, bucketed_all_reduce_time,
                             hierarchical_all_reduce_time,
                             ring_all_reduce_time, scaling_efficiency)
from repro.core.dlplacer import (DFG, HardwareGraph, OpCost, list_schedule,
                                 simulated_silicon, solve_placement)
from repro.core.stateff import (EpochModel, fit_epoch_model,
                                PAPER_FIG4, paper_epoch_model,
                                paper_epoch_table)


def run_for(name="net", su2=1.32, se_perfect=True, b_crit=2048,
            alpha=2.0, mini=64):
    return TrainingRun(
        name=name, t1=0.1, grad_bytes=4 * 25e6, mini_batch=mini,
        epoch_model=EpochModel(e_inf=4.0, b_crit=b_crit, alpha=alpha),
        dataset_size=1_281_167,  # imagenet
        mp_speedup={2: su2, 4: 1.65},
        se_perfect=se_perfect)


# ---- Eq. 3/5 identities ----------------------------------------------------

def test_eq3_single_device_is_identity():
    run = run_for()
    assert speedup_dp(run, 1) == pytest.approx(1.0)


def test_eq5_reduces_to_eq3_when_m1():
    run = run_for()
    for n in (2, 8, 64):
        assert speedup_hybrid(run, n, 1) == pytest.approx(speedup_dp(run, n))


def test_eq5_scales_by_su_m():
    """SU_N^M = SU^M x SU_N exactly (same N) — Eq. 5 vs Eq. 3."""
    run = run_for()
    for n in (4, 32, 128):
        assert speedup_hybrid(run, n, 2) == pytest.approx(
            1.32 * speedup_dp(run, n))


def test_eq6_criterion_equivalence():
    """hybrid_wins must equal the inequality form of Eq. 6."""
    run = run_for(se_perfect=True)
    for n in (8, 16, 32, 64, 128):
        m = 2
        lhs = run.mp_speedup[m]
        e_n = run.epoch_model.epochs(n * run.mini_batch)
        e_mn = run.epoch_model.epochs(m * n * run.mini_batch)
        rhs = m * 1.0 * (e_n / e_mn)   # SE ratio = 1 in perfect mode
        assert hybrid_wins(run, n, m) == (lhs > rhs)


def test_dp_speedup_monotone_saturates():
    """SU_N grows then saturates/declines as statistical efficiency dies."""
    run = run_for()
    sus = [speedup_dp(run, n) for n in (1, 2, 4, 8, 16, 32, 64, 128, 256, 512)]
    assert sus[1] > sus[0]
    assert max(sus) > 5
    assert sus[-1] < max(sus)  # past the statistical-efficiency cliff


def test_crossover_exists_and_moves_with_su_m():
    """Higher SU^M => earlier (or equal) crossover — paper §3.4."""
    weak = crossover_device_count(run_for(su2=1.05), m=2)
    strong = crossover_device_count(run_for(su2=1.6), m=2)
    assert strong is not None
    if weak is not None:
        assert strong <= weak


def test_paper_claim_inception_hybrid_at_scale():
    """With the paper's Fig. 4 Inception-V3 epochs and SU^2 = 1.32, hybrid
    must beat DP-only by >= 26.5% at 256 GPUs and >= 15.5% at 64 (paper §5)."""
    run = TrainingRun(
        name="inception_v3", t1=0.1, grad_bytes=4 * 25e6, mini_batch=64,
        epoch_model=paper_epoch_table("inception_v3"),
        dataset_size=1_281_167, mp_speedup={2: 1.32}, se_perfect=True)
    for total, min_gain in [(64, 1.15), (256, 1.26)]:
        hyb = speedup_hybrid(run, total // 2, 2)
        dp = speedup_dp(run, total)
        assert hyb / dp >= min_gain, (total, hyb / dp)


def test_paper_claim_biglstm():
    """BigLSTM: hybrid at 32 devices beats DP-only best (paper: 1.22x)."""
    run = TrainingRun(
        name="biglstm", t1=0.5, grad_bytes=4 * 420e6, mini_batch=128,
        epoch_model=paper_epoch_table("biglstm"),
        dataset_size=768_000, mp_speedup={2: 1.22}, se_perfect=True)
    hyb32 = speedup_hybrid(run, 16, 2)
    dp_best = max(speedup_dp(run, n) for n in (8, 16, 32))
    assert hyb32 / dp_best >= 1.1


def test_best_strategy_argmax():
    run = run_for()
    best = best_strategy(run, 256)
    # must match explicit enumeration
    cands = [speedup_dp(run, 256), speedup_hybrid(run, 128, 2),
             speedup_hybrid(run, 64, 4)]
    assert best["speedup"] == pytest.approx(max(cands))


# ---- comm model -------------------------------------------------------------

def test_ring_all_reduce_classic_form():
    t = ring_all_reduce_time(1e9, 4, 100e9, 0.0)
    assert t == pytest.approx(2 * 3 / 4 * 1e9 / 100e9)


def test_ring_all_reduce_monotone_in_n():
    ts = [ring_all_reduce_time(1e9, n, 100e9, 1e-6) for n in (2, 4, 8, 64, 512)]
    assert all(b >= a for a, b in zip(ts, ts[1:]))


def test_hierarchical_cliff_at_pod_boundary():
    """Crossing the pod boundary must cost extra (the SE_{M*N} cliff)."""
    hw = HardwareModel()
    t_in = hierarchical_all_reduce_time(1e9, 256, hw, 256)
    t_out = hierarchical_all_reduce_time(1e9, 512, hw, 256)
    assert t_out > t_in


def test_scaling_efficiency_bounds():
    hw = HardwareModel()
    for n in (1, 2, 16, 256, 512):
        se = scaling_efficiency(1e9, 0.1, n, hw)
        assert 0 < se <= 1.0
    assert scaling_efficiency(1e9, 0.1, 256, hw, assume_perfect=True) == 1.0


def test_hierarchical_equals_ring_within_pod():
    """n <= intra-pod degree must be exactly the single ICI ring."""
    hw = HardwareModel()
    for n in (2, 64, 256):
        assert hierarchical_all_reduce_time(1e9, n, hw, 256) == pytest.approx(
            ring_all_reduce_time(1e9, n, hw.ici_bw, hw.ici_latency))


def test_hierarchical_composes_intra_plus_inter():
    """Past the pod boundary: full-size ICI ring intra-pod plus a DCI ring
    over pods carrying the 1/degree reduce-scattered shard."""
    hw = HardwareModel()
    n, degree = 1024, 256
    got = hierarchical_all_reduce_time(1e9, n, hw, degree)
    t_intra = ring_all_reduce_time(1e9, degree, hw.ici_bw, hw.ici_latency)
    t_inter = ring_all_reduce_time(1e9 / degree, n // degree, hw.dci_bw,
                                   hw.dci_latency)
    assert got == pytest.approx(t_intra + t_inter)


def test_bucketed_all_reduce_alpha_cost():
    """Same wire time as the fused ring, plus 2*(n-1) hop latencies per
    bucket — monotone in the bucket count, so tiny buckets are penalized."""
    bw, lat, n, b = 100e9, 1e-6, 8, 1e9
    fused = ring_all_reduce_time(b, n, bw, lat)
    one = bucketed_all_reduce_time(b, n, bw, lat, bucket_bytes=b)
    assert one == pytest.approx(2 * (n - 1) / n * b / bw + 2 * (n - 1) * lat)
    assert one >= fused
    ts = [bucketed_all_reduce_time(b, n, bw, lat, bucket_bytes=b / k)
          for k in (1, 4, 16, 64)]
    assert all(t2 > t1 for t1, t2 in zip(ts, ts[1:]))
    assert bucketed_all_reduce_time(b, 1, bw, lat, bucket_bytes=b) == 0.0


def test_scaling_efficiency_overlap_and_buckets():
    """Overlap raises SE; the bucketed alpha cost lowers it (slightly)."""
    hw = HardwareModel()
    base = scaling_efficiency(1e9, 0.1, 256, hw)
    over = scaling_efficiency(1e9, 0.1, 256, hw, overlap=0.6)
    assert over > base
    bucketed = scaling_efficiency(1e9, 0.1, 256, hw, bucket_bytes=1e6)
    assert bucketed <= base


# ---- planner pods interaction ----------------------------------------------

def _planner_for(arch="biglstm"):
    from repro.configs import get_config
    from repro.core.planner import HybridPlanner, default_epoch_model
    cfg = get_config(arch)
    return HybridPlanner(cfg, epoch_model=default_epoch_model(cfg))


def test_planner_pods_factorization():
    """_pods splits the budget at chips_per_pod (256) boundaries only when
    the pod count divides both the budget and the DP degree."""
    p = _planner_for()
    assert p._pods(512, 256) == 2
    assert p._pods(512, 16) == 2
    assert p._pods(1024, 128) == 4
    assert p._pods(256, 256) == 1          # single pod
    assert p._pods(300, 300) == 1          # not a pod multiple
    assert p._pods(1024, 255) == 1         # pods would not divide n


def test_planner_multi_pod_choices_cross_pod_se():
    """At 1024 devices the emitted multi-pod plans must carry the pod axis
    in dp_axes / mesh_shape, n_workers must recompose pods*dp, and SE_N must
    pay the hierarchical DCI cliff relative to an intra-pod point of the
    same per-pod DP degree."""
    p = _planner_for()
    multi = [c for c in p.choices(1024) if c.pods > 1]
    assert multi, "no multi-pod choices at 1024 devices"
    for c in multi:
        assert c.plan.dp_axes == ("pod", "data")
        assert c.mesh_shape[0] == c.pods
        assert c.n_workers == c.pods * c.dp
        assert c.n_workers * c.mp <= 1024
    # SE cliff: crossing pods with the same total N is worse than intra-pod
    se_intra = p._se(256, 1)
    se_cross = p._se(512, 1)
    assert se_cross < se_intra


# ---- epoch model -----------------------------------------------------------

def test_fit_epoch_model_recovers_curve():
    true = EpochModel(e_inf=4.0, b_crit=3000.0, alpha=2.0)
    pts = {b: true.epochs(b) for b in (256, 512, 1024, 2048, 4096, 8192)}
    fit = fit_epoch_model({int(k): v for k, v in pts.items()})
    for b in (300, 1000, 5000):
        assert fit.epochs(b) == pytest.approx(true.epochs(b), rel=0.15)


def test_paper_fig4_fits_are_monotone():
    for net in PAPER_FIG4:
        m = paper_epoch_model(net)
        bs = sorted(PAPER_FIG4[net])
        es = [m.epochs(b) for b in bs if m.epochs(b) != float("inf")]
        assert all(b >= a - 1e-9 for a, b in zip(es, es[1:]))


def test_biglstm_divergence_encoded():
    m = paper_epoch_model("biglstm")
    assert m.epochs(8192) == float("inf")  # did not converge past 32-way


# ---- DLPlacer ---------------------------------------------------------------

def chain_dfg(n=6, flops=1e9):
    nodes = {f"n{i}": OpCost(flops, 1e6) for i in range(n)}
    edges = [(f"n{i}", f"n{i+1}") for i in range(n - 1)]
    return DFG(nodes, edges)


def diamond_dfg(width=2, flops=1e9, bytes_out=1e4):
    nodes = {"src": OpCost(flops / 10, bytes_out)}
    edges = []
    for i in range(width):
        nodes[f"b{i}"] = OpCost(flops, bytes_out)
        edges.append(("src", f"b{i}"))
    nodes["sink"] = OpCost(flops / 10, bytes_out)
    edges += [(f"b{i}", "sink") for i in range(width)]
    return DFG(nodes, edges)


def test_chain_gets_no_mp_speedup():
    """A pure chain has no parallelism: optimal 2-device = 1-device time."""
    dfg = chain_dfg()
    hw = HardwareGraph(n_devices=2)
    res = solve_placement(dfg, hw, time_budget_s=20)
    assert res.makespan == pytest.approx(res.single_device_time, rel=1e-6)


def test_diamond_gets_2x():
    """Two independent equal branches on 2 devices -> ~2x on the branch part."""
    dfg = diamond_dfg(2)
    hw = HardwareGraph(n_devices=2)
    res = solve_placement(dfg, hw, time_budget_s=20)
    t1 = res.single_device_time
    # branches parallelize: expected ~ (0.1 + 1 + 0.1)/(0.1+0.1+2) x
    assert res.makespan < 0.65 * t1
    assert res.optimal


def test_solver_beats_or_matches_trivial_placements():
    dfg = diamond_dfg(4)
    hw = HardwareGraph(n_devices=2)
    res = solve_placement(dfg, hw, time_budget_s=20)
    all_on_0 = {n: 0 for n in dfg.nodes}
    assert res.makespan <= list_schedule(dfg, hw, all_on_0) + 1e-9
    assert res.makespan >= res.lower_bound - 1e-6


def test_comm_cost_prevents_silly_splits():
    """Huge activations => optimal placement keeps the chain on one device."""
    nodes = {f"n{i}": OpCost(1e8, 1e9) for i in range(4)}  # 1 GB edges!
    edges = [(f"n{i}", f"n{i+1}") for i in range(3)]
    hw = HardwareGraph(n_devices=2)
    res = solve_placement(DFG(nodes, edges), hw, time_budget_s=20)
    devices = set(res.placement.values())
    assert len(devices) == 1


def test_memory_constraint_forces_split():
    """Eq. 13: ops that don't fit on one device must spread."""
    nodes = {f"n{i}": OpCost(1e9, 1e3, mem=10e9) for i in range(4)}
    dfg = DFG(nodes, [])
    hw = HardwareGraph(n_devices=4, mem_capacity=16e9)
    res = solve_placement(dfg, hw, time_budget_s=30)
    from repro.core.dlplacer import memory_ok
    assert memory_ok(dfg, hw, res.placement)
    assert len(set(res.placement.values())) >= 3


def test_simulated_silicon_close_to_prediction():
    """Fig. 8 validation harness: the simulated-silicon makespan with
    framework overheads stays within ~10% of DLPlacer's prediction for the
    Inception DFG (paper reports 6%)."""
    from repro.models.inception import inception_dfg
    nodes, edges = inception_dfg(batch=32)
    dfg = DFG.from_analytic(nodes, edges)
    hw = HardwareGraph(n_devices=2)
    res = solve_placement(dfg, hw, time_budget_s=30)
    sil = simulated_silicon(dfg, hw, res.placement)
    assert abs(sil - res.makespan) / res.makespan < 0.15
    assert res.speedup_vs_single > 1.0  # branches give real MP speedup
