"""Hand-scheduled fwd+bwd pipeline runtime (ISSUE 3 tentpole).

Two properties make the scheduled runtime *the* runtime rather than a
curiosity, and both are pinned here:

1. **Residency realization** — the runtime's live-buffer high-water mark
   (the activation store ``plan_scheduled_runtime`` actually allocates)
   equals the closed-form ``activation_residency()`` the planner's memory
   filter assumes: min(K, S) for 1f1b vs K for gpipe, strictly fewer at
   K > S.  The ad runtime cannot realize this (AD-through-scan stashes all
   K micro-batches across the fwd->bwd transpose).
2. **Differential correctness** — loss and every gradient (stage params,
   loss params, input cotangent) match ``jax.value_and_grad`` through the
   ad runtime to fp32 round-off on the schedule x stages x micro grid.
"""
import subprocess
import sys
import os
import textwrap

import numpy as np
import pytest

from repro.parallel.pipeline import (PipelineSchedule, SCHEDULE_KINDS,
                                     make_schedule,
                                     pipeline_activation_residency,
                                     plan_scheduled_runtime, stack_to_stages,
                                     stages_to_stack)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

GRID = [(S, K) for S in (2, 3, 4) for K in (1, 2, 4, 8, 16)]


def _run_subprocess(code: str):
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


# ---------------------------------------------------------------------------
# 1. residency realization (pure — the store the runtime allocates)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", SCHEDULE_KINDS)
@pytest.mark.parametrize("S,K", GRID)
def test_store_high_water_equals_residency(kind, S, K):
    """The satellite metric: the scheduled runtime's live-buffer high-water
    mark — max over (stage, tick) of concurrently-stashed stage inputs —
    equals the schedule's closed-form activation residency.  For the v=1
    schedules that is exact in micro-batches (1f1b: min(K, S); gpipe: K);
    interleaved counts chunk inputs, residency * v of them."""
    sched = make_schedule(kind, S, K)
    rtp = plan_scheduled_runtime(sched)
    assert rtp.high_water == rtp.fwd_slots  # store sized exactly at the peak
    bound = sched.activation_residency() * sched.v
    if kind == "interleaved":
        # interleaved may buffer up to v-1 in-transit wrap chunks above the
        # closed-form held-activation bound (covered by the planner's
        # ring-buffer term), and can never fall below what the exec table
        # holds
        assert sched.residency_from_table() * sched.v <= rtp.fwd_slots \
            <= round(bound) + sched.v - 1, (S, K, rtp.fwd_slots, bound)
    else:
        assert rtp.fwd_slots == round(bound), (kind, S, K, rtp.fwd_slots)
    if kind == "1f1b":
        assert rtp.fwd_slots == min(K, S)
    if kind == "gpipe":
        assert rtp.fwd_slots == K


@pytest.mark.parametrize("S,K", [(2, 4), (2, 8), (4, 8), (4, 16)])
def test_1f1b_store_strictly_smaller_than_gpipe(S, K):
    """The acceptance criterion: at K > S the scheduled runtime's 1f1b
    activation store is strictly smaller than gpipe's — the memory win the
    planner's arg-max (1f1b@K=16) banks on, now realized by the executor."""
    assert K > S
    g = plan_scheduled_runtime(make_schedule("gpipe", S, K))
    f = plan_scheduled_runtime(make_schedule("1f1b", S, K))
    assert f.fwd_slots == S < K == g.fwd_slots, (S, K, f, g)
    # total ticks are identical — 1f1b trades nothing for the memory
    assert f.n_ticks == g.n_ticks == 2 * (K + S - 1)


@pytest.mark.parametrize("kind", SCHEDULE_KINDS)
@pytest.mark.parametrize("S,K", [(2, 4), (4, 4), (4, 8)])
def test_runtime_plan_tables_consistent(kind, S, K):
    """Structural invariants of the compiled tick tables: cells mirror the
    WorkUnit table, every slot index is within the allocated store, every
    non-injected forward input arrives over the ring exactly once before
    (or at) its exec tick, and every backward reads a slot a forward
    stashed."""
    sched = make_schedule(kind, S, K)
    rtp = plan_scheduled_runtime(sched)
    t = rtp.tables
    n_fwd = int((t["op"] == 1).sum())
    n_bwd = int((t["op"] == 2).sum())
    assert n_fwd == n_bwd == K * sched.n_virtual
    assert rtp.n_ticks == t["op"].shape[0] == sched.total_ticks()
    # slot bounds
    for name in ("f_slot", "f_arr", "b_act"):
        assert t[name].max() < rtp.fwd_slots
    for name in ("b_seed", "b_arr", "b_rd"):
        assert t[name].max() < rtp.bwd_slots
    # every fwd unit has a slot; injected units own stash writes, the rest
    # match one ring arrival at an earlier-or-equal tick
    fwd_cells = np.argwhere(t["op"] == 1)
    n_inject = sum(int(t["f_inject"][tt, s]) for tt, s in fwd_cells)
    n_arrivals = int((t["f_arr"] >= 0).sum())
    assert n_arrivals == n_fwd - n_inject
    for tt, s in fwd_cells:
        assert t["f_slot"][tt, s] >= 0
        if not t["f_inject"][tt, s]:
            arr_ticks = np.argwhere(
                (t["f_arr"][:tt + 1, s] == t["f_slot"][tt, s]))
            assert arr_ticks.size >= 1, (kind, S, K, tt, s)
    # every bwd unit pops a stashed input and an incoming cotangent
    for tt, s in np.argwhere(t["op"] == 2):
        assert t["b_act"][tt, s] >= 0 and t["b_rd"][tt, s] >= 0
    # the last virtual stage emits exactly one loss seed per micro-batch
    assert int((t["b_seed"] >= 0).sum()) == K


def test_activation_residency_keyed_off_runtime():
    """The planner's memory filter input: on the ad runtime every schedule
    holds all K micro-batches (jax AD stashes the full forward before the
    backward), so 1f1b's residency edge exists only under the scheduled
    runtime."""
    for S, K in GRID:
        for kind in SCHEDULE_KINDS:
            ad = pipeline_activation_residency(K, S, kind, 2, runtime="ad")
            sc = pipeline_activation_residency(K, S, kind, 2,
                                               runtime="scheduled")
            assert ad == K
            assert sc <= ad
    assert pipeline_activation_residency(16, 4, "1f1b",
                                         runtime="scheduled") == 4


def test_planner_memory_model_follows_runtime():
    """HybridPlanner(pipe_runtime="ad") must cost 1f1b like gpipe (no
    residency discount) and stamp the runtime into the emitted plans."""
    from repro.configs import get_config
    from repro.core.planner import (HybridPlanner, default_epoch_model,
                                    per_device_mem_bytes)
    cfg = get_config("biglstm")
    kw = dict(mp=2, mp_kind="pipeline", fsdp=1, mini_batch=64, seq_len=4096,
              remat=False, microbatches=16)
    mem_ad = per_device_mem_bytes(cfg, schedule="1f1b", pipe_runtime="ad",
                                  **kw)
    mem_sc = per_device_mem_bytes(cfg, schedule="1f1b",
                                  pipe_runtime="scheduled", **kw)
    mem_gp = per_device_mem_bytes(cfg, schedule="gpipe",
                                  pipe_runtime="ad", **kw)
    assert mem_ad == mem_gp > mem_sc
    for rt in ("scheduled", "ad"):
        planner = HybridPlanner(cfg, epoch_model=default_epoch_model(cfg),
                                pipe_runtime=rt)
        best = planner.best(256)
        assert best.mp_kind == "pipeline"
        assert best.plan.runtime == rt
    with pytest.raises(ValueError):
        HybridPlanner(cfg, epoch_model=default_epoch_model(cfg),
                      pipe_runtime="bogus")


def test_plan_runtime_field_validated():
    from repro.parallel.plan import ParallelPlan
    with pytest.raises(ValueError, match="runtime"):
        ParallelPlan(runtime="bogus")
    assert ParallelPlan().runtime == "scheduled"
    assert "scheduled runtime" in ParallelPlan(
        mp_kind="pipeline", microbatches=4).describe(
            _FakeMesh({"data": 2, "model": 2}))


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_residual_store_spec_layout():
    """The scheduled runtime's activation store, viewed as a logical
    (stages, slots, mb, ...) array, is stage-local on the model axis with
    the micro-batch dim over DP — matching the in-shard_map carry."""
    from repro.configs import get_config
    from repro.parallel.plan import ParallelPlan
    from repro.parallel.sharding import ShardingRules
    rules = ShardingRules(get_config("biglstm"),
                          _FakeMesh({"data": 4, "model": 4}),
                          ParallelPlan(mp_kind="pipeline", microbatches=4))
    spec = rules.residual_store_spec(4)
    assert tuple(spec) == ("model", None, ("data",), None)
    with pytest.raises(ValueError):
        rules.residual_store_spec(2)


def test_stack_to_stages_shaped_error():
    """ISSUE 3 satellite: a non-divisible layer stack must raise a shaped
    error naming the offending sizes, not silently mis-reshape."""
    import jax.numpy as jnp
    params = {"w": jnp.zeros((6, 3, 3))}
    with pytest.raises(ValueError, match=r"6.*n_stages \* virtual_stages"):
        stack_to_stages(params, 4)
    with pytest.raises(ValueError, match="not\n?.*divisible|divisible"):
        stack_to_stages(params, 2, 2)
    # the inverse validates its layout too
    with pytest.raises(ValueError, match="stages_to_stack"):
        stages_to_stack({"w": jnp.zeros((2, 2, 1, 3))}, 4, 1)
    rt = stages_to_stack(stack_to_stages(params, 3), 3)
    assert rt["w"].shape == (6, 3, 3)


# ---------------------------------------------------------------------------
# 2. differential correctness vs the ad runtime
# ---------------------------------------------------------------------------

_GRID_RUNNER = """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp
    from repro.parallel.jaxcompat import make_mesh, set_mesh
    from repro.parallel.pipeline import (pipeline_apply,
                                         pipeline_value_and_grad,
                                         stack_to_stages)

    L, d, B = 8, 16, 24
    key = jax.random.PRNGKey(0)
    params = {{"w": jax.random.normal(key, (L, d, d)) * 0.1,
               "b": jnp.zeros((L, d))}}
    x = jax.random.normal(jax.random.PRNGKey(1), (B, d))
    tgt = jax.random.normal(jax.random.PRNGKey(2), (B, d))

    def stage_fn(sp, x):
        y, _ = jax.lax.scan(
            lambda x, lp: (jnp.tanh(x @ lp["w"] + lp["b"]), None), x, sp)
        return y

    def loss_fn(lp, y_m, t_m):
        return ((y_m * lp["scale"] - t_m) ** 2).sum()

    lp = {{"scale": jnp.float32(1.3)}}
    for stages in {stages_list}:
        mesh = make_mesh((1, stages), ("data", "model"))
        for sched in ("gpipe", "1f1b", "interleaved"):
            v = 2 if sched == "interleaved" else 1
            stacked = stack_to_stages(params, stages, v)
            for K in (2, 4, 8):
                def ad_loss(stk, lpp, xx):
                    y = pipeline_apply(mesh, "model", stage_fn, stk, xx,
                                       n_micro=K, schedule=sched,
                                       virtual_stages=v)
                    ym = y.reshape((K, B // K, d))
                    tm = tgt.reshape((K, B // K, d))
                    return jax.vmap(
                        lambda a, b: loss_fn(lpp, a, b))(ym, tm).sum()
                with set_mesh(mesh):
                    ref_l, ref_g = jax.jit(jax.value_and_grad(
                        ad_loss, argnums=(0, 1, 2)))(stacked, lp, x)
                    out_l, out_g = jax.jit(
                        lambda stk, lpp, xx: pipeline_value_and_grad(
                            mesh, "model", stage_fn, stk, xx,
                            loss_fn=loss_fn, loss_params=lpp, targets=tgt,
                            n_micro=K, schedule=sched,
                            virtual_stages=v))(stacked, lp, x)
                rel_l = abs(float(ref_l - out_l)) / abs(float(ref_l))
                errs = jax.tree.map(
                    lambda a, b: float(jnp.abs(a - b).max()), ref_g, out_g)
                err_g = max(jax.tree.leaves(errs))
                assert rel_l < 1e-5 and err_g < 1e-5, \\
                    (stages, sched, K, rel_l, errs)
                print("OK", stages, sched, K, rel_l, err_g)
"""


def test_scheduled_matches_ad_grid_2stage():
    """Every (schedule, K) point at S=2: loss + stage-param grads +
    loss-param grads + input cotangent all match jax.value_and_grad of the
    ad runtime to fp32 round-off."""
    out = _run_subprocess(_GRID_RUNNER.format(stages_list="(2,)"))
    assert out.count("OK") == 9


@pytest.mark.slow
def test_scheduled_matches_ad_grid_4stage():
    """Same grid at S=4 (the deeper warmup/drain and wrap-ring paths)."""
    out = _run_subprocess(_GRID_RUNNER.format(stages_list="(4,)"))
    assert out.count("OK") == 9


def test_scheduled_model_grads_equal_ad_dp_stages():
    """Model-level (the train-step path): biglstm on a 2x2 dp x stages
    mesh, scheduled runtime ((loss, metrics), grads) vs jax.value_and_grad
    of the ad pipeline loss — loss and every param grad equal to fp32
    round-off, embed/head included (the vjp'd pre/post parts)."""
    _run_subprocess("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        from repro.parallel.jaxcompat import make_mesh, set_mesh
        from repro.configs import get_config
        from repro.models.api import build_model

        for arch in ("biglstm", "smollm_360m"):
            cfg = get_config(arch).reduced()
            api = build_model(cfg, remat=False)
            key = jax.random.PRNGKey(0)
            params = api.init(key)
            batch = {"tokens": jax.random.randint(key, (8, 16), 0,
                                                  cfg.vocab_size,
                                                  dtype=jnp.int32),
                     "labels": jax.random.randint(key, (8, 16), 0,
                                                  cfg.vocab_size,
                                                  dtype=jnp.int32)}
            mesh = make_mesh((2, 2), ("data", "model"))

            def ad_loss(p, b):
                return api.pipeline_loss_fn(p, b, mesh=mesh, axis="model",
                                            n_micro=4, schedule="1f1b",
                                            batch_axes=("data",))[0]

            with set_mesh(mesh):
                ref_l, ref_g = jax.jit(jax.value_and_grad(ad_loss))(params,
                                                                    batch)
                (out_l, _), out_g = jax.jit(
                    lambda p, b: api.pipeline_value_and_grad_fn(
                        p, b, mesh=mesh, axis="model", n_micro=4,
                        schedule="1f1b", batch_axes=("data",)))(params,
                                                                batch)
            err_l = abs(float(ref_l) - float(out_l))
            errs = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                                ref_g, out_g)
            err_g = max(jax.tree.leaves(errs))
            assert err_l < 1e-5 and err_g < 1e-5, (arch, err_l, err_g)
            print("OK", arch, err_l, err_g)
    """)


def test_train_step_scheduled_vs_ad_runtime_bit_for_bit():
    """The full train step (grads -> clip -> adamw update) produces the
    same post-step loss under both runtimes of the same 1f1b plan — the
    ISSUE 3 differential-testing escape hatch."""
    _run_subprocess("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import dataclasses
        import jax, jax.numpy as jnp
        from repro.parallel.jaxcompat import make_mesh, set_mesh
        from repro.configs import get_config
        from repro.models.api import build_model
        from repro.optim import adamw, constant_lr
        from repro.parallel.plan import ParallelPlan
        from repro.train.steps import init_train_state, make_train_step

        cfg = get_config("biglstm").reduced()
        api = build_model(cfg)
        opt = adamw(constant_lr(1e-3))
        mesh = make_mesh((2, 2), ("data", "model"))
        key = jax.random.PRNGKey(0)
        batch = {"tokens": jax.random.randint(key, (8, 16), 0,
                                              cfg.vocab_size,
                                              dtype=jnp.int32),
                 "labels": jax.random.randint(key, (8, 16), 0,
                                              cfg.vocab_size,
                                              dtype=jnp.int32)}
        plan = ParallelPlan(mp_kind="pipeline", microbatches=4,
                            schedule="1f1b")
        losses = {}
        for rt in ("scheduled", "ad"):
            p = dataclasses.replace(plan, runtime=rt)
            step = make_train_step(api, opt, mesh=mesh, plan=p)
            state = init_train_state(api, opt, jax.random.PRNGKey(0))
            with set_mesh(mesh):
                step = jax.jit(step)
                for _ in range(2):
                    state, metrics = step(state, batch)
            losses[rt] = float(metrics["loss"])
        diff = abs(losses["scheduled"] - losses["ad"])
        assert diff < 1e-5, losses
        print("OK", losses)
    """)
