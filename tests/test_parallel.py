"""Parallel runtime: sharding-rule invariants (pure), plus multi-device
equivalence properties (sharded loss == single-device loss; pipeline ==
sequential) run in subprocesses so only they see forced host devices."""
import os
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model
from repro.parallel.plan import ParallelPlan
from repro.parallel.sharding import ShardingRules

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)

    @property
    def size(self):
        n = 1
        for v in self.shape.values():
            n *= v
        return n


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_sharding_specs_divisibility(arch):
    """Every assigned spec must divide its dim by the mesh axis product —
    the invariant that makes the production jit accept the shardings."""
    cfg = get_config(arch)
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = ShardingRules(cfg, mesh, ParallelPlan(fsdp_axes=("data",)))
    api = build_model(cfg)
    params_shape = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    specs = rules.params_specs(params_shape)

    flat_p, _ = jax.tree_util.tree_flatten_with_path(params_shape)
    flat_s = jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    assert len(flat_p) == len(flat_s)
    sizes = {"data": 16, "model": 16}
    n_model_sharded = 0
    for (path, leaf), spec in zip(flat_p, flat_s):
        assert len(spec) <= len(leaf.shape), (path, spec, leaf.shape)
        for dim, entry in zip(leaf.shape, tuple(spec)):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            prod = 1
            for a in axes:
                prod *= sizes[a]
            assert dim % prod == 0, (jax.tree_util.keystr(path), leaf.shape,
                                     spec)
            if "model" in axes:
                n_model_sharded += 1
    assert n_model_sharded > 0, f"{arch}: nothing model-sharded"


@pytest.mark.parametrize("arch", ["kimi_k2_1t_a32b", "nemotron_4_340b"])
def test_giant_archs_fit_when_fully_sharded(arch):
    """Param bytes per chip under the optimized (fsdp) plan must be < HBM."""
    cfg = get_config(arch)
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    rules = ShardingRules(cfg, mesh,
                          ParallelPlan(dp_axes=("pod", "data"),
                                       fsdp_axes=("pod", "data")))
    api = build_model(cfg)
    params_shape = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    specs = rules.params_specs(params_shape)
    flat_p, _ = jax.tree_util.tree_flatten_with_path(params_shape)
    flat_s = jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    sizes = {"pod": 2, "data": 16, "model": 16}
    per_chip = 0
    for (path, leaf), spec in zip(flat_p, flat_s):
        n = leaf.dtype.itemsize
        for d in leaf.shape:
            n *= d
        div = 1
        for entry in tuple(spec):
            if entry is None:
                continue
            for a in (entry if isinstance(entry, tuple) else (entry,)):
                div *= sizes[a]
        per_chip += n / div
    # f32 master params sharded over 512 chips
    assert per_chip < 16e9, f"{arch}: {per_chip/2**30:.1f} GiB/chip"


def _run_subprocess(code: str):
    # pin the subprocess to CPU: the container ships a libtpu that otherwise
    # burns ~8 minutes probing for TPU metadata before falling back, and the
    # forced host-device count only applies to the cpu platform anyway
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


def test_sharded_loss_equals_single_device():
    """4-way DP x 2-way MP loss == single-device loss (fp32, same batch)."""
    _run_subprocess("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel.jaxcompat import make_mesh, set_mesh
        from repro.configs import get_config
        from repro.models import build_model
        from repro.parallel.plan import ParallelPlan
        from repro.parallel.sharding import ShardingRules

        cfg = get_config("llama3_2_1b").reduced()
        api = build_model(cfg, remat=False)
        key = jax.random.PRNGKey(0)
        params = api.init(key)
        batch = {"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab_size, dtype=jnp.int32),
                 "labels": jax.random.randint(key, (8, 32), 0, cfg.vocab_size, dtype=jnp.int32)}
        ref, _ = api.loss_fn(params, batch)

        mesh = make_mesh((4, 2), ("data", "model"))
        plan = ParallelPlan()
        rules = ShardingRules(cfg, mesh, plan)
        p_sh = rules.params_shardings(jax.eval_shape(api.init, key))
        b_sh = rules.batch_shardings(jax.eval_shape(lambda: batch))
        with set_mesh(mesh):
            f = jax.jit(lambda p, b: api.loss_fn(p, b)[0],
                        in_shardings=(p_sh, b_sh))
            sharded = f(params, batch)
        err = abs(float(ref) - float(sharded))
        assert err < 1e-4, (float(ref), float(sharded))
        print("OK", float(ref), float(sharded))
    """)


def test_moe_ep_shard_map_equals_local():
    """Expert-parallel shard_map MoE == local (mp=1) MoE."""
    _run_subprocess("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from repro.parallel.jaxcompat import make_mesh, set_mesh
        from repro.configs import get_config
        from repro.models import build_model
        from repro.models.transformer import ParallelCtx
        from repro.parallel.plan import ParallelPlan
        from repro.parallel.sharding import ShardingRules

        cfg = get_config("granite_moe_1b_a400m").reduced()
        api = build_model(cfg, remat=False, capacity_factor=None)
        key = jax.random.PRNGKey(0)
        params = api.init(key)
        batch = {"tokens": jax.random.randint(key, (8, 16), 0, cfg.vocab_size, dtype=jnp.int32),
                 "labels": jax.random.randint(key, (8, 16), 0, cfg.vocab_size, dtype=jnp.int32)}
        ref, _ = api.loss_fn(params, batch)

        mesh = make_mesh((2, 4), ("data", "model"))
        pctx = ParallelCtx(mesh=mesh, batch_axes=("data",), model_axis="model")
        rules = ShardingRules(cfg, mesh, ParallelPlan())
        p_sh = rules.params_shardings(jax.eval_shape(api.init, key))
        b_sh = rules.batch_shardings(jax.eval_shape(lambda: batch))
        with set_mesh(mesh):
            f = jax.jit(lambda p, b: api.loss_fn(p, b, pctx)[0],
                        in_shardings=(p_sh, b_sh))
            ep = f(params, batch)
        # tolerance covers fp32 reduction-order drift across jax versions
        # (the EP psum tree differs between shard_map implementations)
        err = abs(float(ref) - float(ep))
        assert err < 3e-3, (float(ref), float(ep))
        print("OK", float(ref), float(ep))
    """)


@pytest.mark.parametrize("stages", [2, 4])
def test_pipeline_schedules_equal_sequential(stages):
    """Bit-exactness of the schedule-generic runtime vs sequential stacking
    (fp32) over the {gpipe, 1f1b, interleaved} x stages x {2, 4, 8} micro
    grid (ISSUE 2 satellite): every schedule must produce identical outputs
    — they reorder/replace the placement, never the math."""
    out = _run_subprocess(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from repro.parallel.jaxcompat import make_mesh, set_mesh
        from repro.parallel.pipeline import pipeline_apply, stack_to_stages

        stages = {stages}
        mesh = make_mesh((1, stages), ("data", "model"))
        L, d, B = 8, 16, 16
        key = jax.random.PRNGKey(0)
        params = {{"w": jax.random.normal(key, (L, d, d)) * 0.1,
                   "b": jnp.zeros((L, d))}}
        x = jax.random.normal(jax.random.PRNGKey(1), (B, d))

        def layer(p, x):
            return jnp.tanh(x @ p["w"] + p["b"])

        def stage_fn(sp, x):
            y, _ = jax.lax.scan(lambda x, lp: (layer(lp, x), None), x, sp)
            return y

        y_ref, _ = jax.lax.scan(lambda x, lp: (layer(lp, x), None), x, params)
        with set_mesh(mesh):
            for sched in ("gpipe", "1f1b", "interleaved"):
                v = 2 if sched == "interleaved" else 1
                for n_micro in (2, 4, 8):
                    y = pipeline_apply(mesh, "model", stage_fn,
                                       stack_to_stages(params, stages, v), x,
                                       n_micro=n_micro, schedule=sched,
                                       virtual_stages=v)
                    err = float(jnp.abs(y - y_ref).max())
                    assert err < 1e-6, (sched, stages, n_micro, err)
                    print("OK", sched, stages, n_micro, err)
    """)
    assert out.count("OK") == 9


def test_pipeline_dp_stages_grads_equal_pure_dp():
    """dp x stages execution (the ISSUE 2 tentpole wiring): a pipeline plan
    on a 2x2 host mesh — batch sharded over "data", stages over "model" —
    must reproduce pure-DP loss AND parameter gradients (fp32) exactly."""
    _run_subprocess("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.parallel.jaxcompat import make_mesh, set_mesh
        from repro.configs import get_config
        from repro.models.api import build_model

        cfg = get_config("biglstm").reduced()
        api = build_model(cfg)
        key = jax.random.PRNGKey(0)
        params = api.init(key)
        batch = {"tokens": jax.random.randint(key, (8, 16), 0, cfg.vocab_size, dtype=jnp.int32),
                 "labels": jax.random.randint(key, (8, 16), 0, cfg.vocab_size, dtype=jnp.int32)}
        mesh = make_mesh((2, 2), ("data", "model"))
        b_sh = {k: NamedSharding(mesh, P("data", None)) for k in batch}
        p_sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), params)

        def dp_loss(p, b):
            return api.loss_fn(p, b)[0]

        def pipe_loss(p, b):
            return api.pipeline_loss_fn(p, b, mesh=mesh, axis="model",
                                        n_micro=2, schedule="1f1b",
                                        batch_axes=("data",))[0]

        with set_mesh(mesh):
            ref_l, ref_g = jax.jit(jax.value_and_grad(dp_loss),
                                   in_shardings=(p_sh, b_sh))(params, batch)
            out_l, out_g = jax.jit(jax.value_and_grad(pipe_loss),
                                   in_shardings=(p_sh, b_sh))(params, batch)
        err_l = abs(float(ref_l) - float(out_l))
        errs = jax.tree.map(
            lambda a, b: float(jnp.abs(a - b).max()), ref_g, out_g)
        err_g = max(jax.tree.leaves(errs))
        assert err_l < 1e-6 and err_g < 1e-6, (err_l, err_g)
        print("OK", err_l, err_g)
    """)


def test_pipeline_output_broadcast_bytes():
    """ISSUE 2 satellite: the old runtime psum'd the FULL outs buffer over
    every stage each step; the new single-source slice must compile to
    strictly fewer collective wire bytes (and no all-reduce of outs-sized
    operands at all)."""
    _run_subprocess("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        from repro.core.roofline import parse_collectives
        from repro.parallel.jaxcompat import make_mesh, set_mesh
        from repro.parallel.pipeline import pipeline_apply, stack_to_stages

        stages, L, d, B, n_micro = 4, 8, 32, 16, 4
        mesh = make_mesh((1, stages), ("data", "model"))
        key = jax.random.PRNGKey(0)
        params = {"w": jax.random.normal(key, (L, d, d)) * 0.1,
                  "b": jnp.zeros((L, d))}
        x = jax.random.normal(jax.random.PRNGKey(1), (B, d))

        def stage_fn(sp, x):
            y, _ = jax.lax.scan(
                lambda x, lp: (jnp.tanh(x @ lp["w"] + lp["b"]), None), x, sp)
            return y

        stacked = stack_to_stages(params, stages)

        def run(replicate_out):
            def f(p, x):
                return pipeline_apply(mesh, "model", stage_fn, p, x,
                                      n_micro=n_micro,
                                      replicate_out=replicate_out).sum()
            with set_mesh(mesh):
                comp = jax.jit(f).lower(stacked, x).compile()
            return parse_collectives(comp.as_text(), default_group=stages)

        new, old = run(False), run(True)
        outs_bytes = B * d * 4
        # the legacy path all-reduces the full (n_micro, mb, d) buffer
        assert old.ops.get("all-reduce", 0) >= 1, old.ops
        assert old.wire_bytes >= outs_bytes, (old.wire_bytes, outs_bytes)
        saved = old.wire_bytes - new.wire_bytes
        assert saved > 0, (old.wire_bytes, new.wire_bytes)
        print("OK saved", saved, "of", old.wire_bytes)
    """)


def test_dryrun_pipeline_lane_stage_sharding():
    """The dryrun ``--plan pipeline`` lane (ISSUE 2 satellite): stage-dim
    sharding rules must put the stacked layer dim of every decoder-stack
    leaf on the model axis (per-stage parameter residency) and keep
    tensor-MP dims unsharded, and the lane itself must lower+compile."""
    import jax as _jax
    from repro.launch.dryrun import make_plan
    cfg = get_config("llama3_2_1b")
    mesh = FakeMesh({"data": 16, "model": 16})
    plan = ParallelPlan(dp_axes=("data",), model_axis="model",
                        mp_kind="pipeline", microbatches=4)
    rules = ShardingRules(cfg, mesh, plan)
    api = build_model(cfg)
    params_shape = _jax.eval_shape(api.init, _jax.random.PRNGKey(0))
    specs = rules.params_specs(params_shape)
    flat_p, _ = _jax.tree_util.tree_flatten_with_path(params_shape)
    flat_s = _jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, _jax.sharding.PartitionSpec))
    n_stage_sharded = 0
    for (path, leaf), spec in zip(flat_p, flat_s):
        keys = [getattr(p, "key", None) for p in path]
        if "layers" in keys:
            assert tuple(spec)[0] == "model", (path, spec)   # stage residency
            assert "model" not in tuple(spec)[1:], (path, spec)
            n_stage_sharded += 1
        else:
            assert "model" not in tuple(spec), (path, spec)  # replicated
    assert n_stage_sharded > 0


@pytest.mark.slow
def test_dryrun_pipeline_lane_compiles():
    """End-to-end pipeline dry-run lane on the production 16x16 mesh."""
    out = _run_subprocess("""
        import sys
        sys.argv = ["dryrun", "--arch", "llama3_2_1b", "--shape", "train_4k",
                    "--mesh", "single", "--plan", "pipeline",
                    "--sched", "1f1b", "--out", "/tmp/dryrun_pipe_test",
                    "--skip-analysis"]
        import shutil
        shutil.rmtree("/tmp/dryrun_pipe_test", ignore_errors=True)
        from repro.launch.dryrun import main
        rc = main()
        assert rc == 0
    """)
    assert "1 ok, 0 failed" in out


def test_pipeline_apply_rejects_chunk_layout_mismatch():
    """A stage-params layout stacked for a different chunk count than the
    schedule's (normalized) v must raise, not silently apply the wrong
    layers — e.g. ``sched=gpipe`` with a v=2 stack would only ever run
    chunk 0."""
    import jax.numpy as jnp
    from repro.parallel.jaxcompat import make_mesh
    from repro.parallel.pipeline import pipeline_apply, stack_to_stages

    mesh = make_mesh((1, 1), ("data", "model"))
    params = {"w": jnp.zeros((2, 3, 3))}
    x = jnp.zeros((4, 3))
    with pytest.raises(ValueError, match="stack_to_stages"):
        pipeline_apply(mesh, "model", lambda p, x: x,
                       stack_to_stages(params, 1, 2), x, n_micro=2,
                       schedule="gpipe")


def test_biglstm_pipeline_loss_equals_sequential():
    """The arch-level pipeline runtime (the one ``--parallel auto`` executes
    for biglstm) matches the plain stacked forward bit-for-bit in fp32."""
    _run_subprocess("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from repro.parallel.jaxcompat import make_mesh, set_mesh
        from repro.configs import get_config
        from repro.models.api import build_model

        cfg = get_config("biglstm").reduced()
        api = build_model(cfg)
        key = jax.random.PRNGKey(0)
        params = api.init(key)
        batch = {"tokens": jax.random.randint(key, (8, 16), 0, cfg.vocab_size, dtype=jnp.int32),
                 "labels": jax.random.randint(key, (8, 16), 0, cfg.vocab_size, dtype=jnp.int32)}
        ref, _ = api.loss_fn(params, batch)
        mesh = make_mesh((1, 2), ("data", "model"))
        with set_mesh(mesh):
            out, _ = jax.jit(lambda p, b: api.pipeline_loss_fn(
                p, b, mesh=mesh, axis="model", n_micro=4))(params, batch)
        err = abs(float(ref) - float(out))
        assert err < 1e-6, (float(ref), float(out))
        print("OK", err)
    """)


def test_dryrun_entrypoint_single_combo():
    """The deliverable-e entrypoint works end to end for one combo on the
    production 16x16 mesh (512 forced host devices)."""
    out = _run_subprocess("""
        import sys
        sys.argv = ["dryrun", "--arch", "llama3_2_1b", "--shape", "decode_32k",
                    "--mesh", "single", "--out", "/tmp/dryrun_test",
                    "--skip-analysis"]
        import shutil
        shutil.rmtree("/tmp/dryrun_test", ignore_errors=True)
        from repro.launch.dryrun import main
        rc = main()
        assert rc == 0
    """)
    assert "1 ok, 0 failed" in out


def test_plan_describe():
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    p = ParallelPlan(dp_axes=("pod", "data"), model_axis="model",
                     fsdp_axes=("pod", "data"), microbatches=4)
    s = p.describe(mesh)
    assert "32-way DP" in s and "16-way" in s and "fsdp" in s and "x4" in s


@pytest.mark.slow
def test_seq_sharded_flash_decode_matches_reference():
    """Flash-decode (KV cache sequence-sharded over the model axis) must
    match single-device cached decode logits (§Perf iteration B.2)."""
    _run_subprocess("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import jax, jax.numpy as jnp
        from repro.parallel.jaxcompat import make_mesh, set_mesh
        from repro.configs import get_config
        from repro.models import build_model
        from repro.models.transformer import ParallelCtx

        # capacity 2048 (>= 1024 threshold), divisible by mp=4
        cfg = get_config("llama3_2_1b").reduced()
        api = build_model(cfg, remat=False)
        key = jax.random.PRNGKey(0)
        params = api.init(key)
        T = 24
        tokens = jax.random.randint(key, (2, T), 0, cfg.vocab_size, dtype=jnp.int32)
        logits, cache = api.prefill(params, {"tokens": tokens[:, :T-2]}, capacity=2048)
        # reference: single-device decode
        ref_logits, ref_cache = api.decode_fn(params, cache, {"tokens": tokens[:, T-2:T-1]})
        mesh = make_mesh((2, 4), ("data", "model"))
        pctx = ParallelCtx(mesh=mesh, batch_axes=("data",), model_axis="model")
        with set_mesh(mesh):
            out, new_cache = jax.jit(
                lambda p, c, b: api.decode_fn(p, c, b, pctx))(
                    params, cache, {"tokens": tokens[:, T-2:T-1]})
        err = float(jnp.abs(out - ref_logits).max())
        assert err < 1e-3, err
        # one more step to exercise the updated cache
        out2, _ = jax.jit(lambda p, c, b: api.decode_fn(p, c, b, pctx))(
            params, new_cache, {"tokens": tokens[:, T-1:T]})
        ref2, _ = api.decode_fn(params, ref_cache, {"tokens": tokens[:, T-1:T]})
        err2 = float(jnp.abs(out2 - ref2).max())
        assert err2 < 1e-3, err2
        print("OK", err, err2)
    """)


@pytest.mark.slow
def test_seq_sharded_flash_decode_windowed():
    """Windowed ring + seq-sharded cache decode must match single-device."""
    _run_subprocess("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import jax, jax.numpy as jnp
        from repro.parallel.jaxcompat import make_mesh, set_mesh
        from repro.configs import get_config
        from repro.models import build_model
        from repro.models.transformer import ParallelCtx

        W = 1024
        cfg = dataclasses.replace(get_config("llama3_2_1b").reduced(),
                                  sliding_window=W)
        api = build_model(cfg, remat=False)
        key = jax.random.PRNGKey(0)
        params = api.init(key)
        T = 16
        tokens = jax.random.randint(key, (2, T), 0, cfg.vocab_size, dtype=jnp.int32)
        logits, cache = api.prefill(params, {"tokens": tokens[:, :T-3]}, capacity=W)
        mesh = make_mesh((2, 4), ("data", "model"))
        pctx = ParallelCtx(mesh=mesh, batch_axes=("data",), model_axis="model")
        # reference: full teacher-forced forward (windowed)
        from repro.models import transformer as tf_mod
        ref, _ = tf_mod.forward(cfg, params, {"tokens": tokens}, mode="train",
                                remat=False)
        # NOTE: prefill produced a shift-left ring; re-layout to positional
        # ring (slot = pos % W) for the seq-sharded path
        def relayout(c):
            pos = int(c["pos"])
            out = dict(c)
            for k in ("k", "v"):
                buf = jnp.zeros_like(c[k])
                n = min(pos, W)
                src = c[k][:, :, W - n:, :, :]
                idx = (jnp.arange(pos - n, pos) % W)
                buf = buf.at[:, :, idx].set(src)
                out[k] = buf
            return out
        cache = relayout(cache)
        errs = []
        with set_mesh(mesh):
            step = jax.jit(lambda p, c, b: api.decode_fn(p, c, b, pctx))
            for t in range(T-3, T):
                out, cache = step(params, cache, {"tokens": tokens[:, t:t+1]})
                errs.append(float(jnp.abs(out[:, 0] - ref[:, t]).max()))
        assert max(errs) < 1e-3, errs
        print("OK", errs)
    """)


def test_vocab_parallel_cross_entropy_matches():
    """Vocab-parallel CE (no logits gather) == plain CE (§Perf iteration D)."""
    _run_subprocess("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from repro.parallel.jaxcompat import make_mesh, set_mesh
        from repro.models.api import cross_entropy, vocab_parallel_cross_entropy

        key = jax.random.PRNGKey(0)
        B, S, V = 4, 16, 64
        logits = jax.random.normal(key, (B, S, V)) * 3.0
        labels = jax.random.randint(key, (B, S), -1, V, dtype=jnp.int32)
        ref = cross_entropy(logits, labels, V)
        mesh = make_mesh((2, 4), ("data", "model"))
        with set_mesh(mesh):
            out = jax.jit(lambda lg, lb: vocab_parallel_cross_entropy(
                lg, lb, V, mesh=mesh, model_axis="model",
                batch_axes=("data",)))(logits, labels)
        err = abs(float(ref) - float(out))
        assert err < 1e-5, (float(ref), float(out))
        # gradient must also match (it feeds the whole backward pass)
        g_ref = jax.grad(lambda lg: cross_entropy(lg, labels, V))(logits)
        with set_mesh(mesh):
            g = jax.jit(jax.grad(lambda lg: vocab_parallel_cross_entropy(
                lg, labels, V, mesh=mesh, model_axis="model",
                batch_axes=("data",))))(logits)
        gerr = float(jnp.abs(g - g_ref).max())
        assert gerr < 1e-6, gerr
        print("OK", err, gerr)
    """)
