"""Serving engine: sampling modes, capacity handling, multi-arch generation,
and checkpoint resharding across plan changes."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.models import build_model
from repro.serve.engine import ServeEngine


def _engine(arch="llama3_2_1b", temperature=0.0, seed=0):
    cfg = get_config(arch).reduced()
    api = build_model(cfg, remat=False)
    params = api.init(jax.random.PRNGKey(seed))
    return cfg, api, ServeEngine(api, params, temperature=temperature)


def test_generation_deterministic_greedy():
    cfg, api, engine = _engine()
    prompt = {"tokens": jnp.arange(8, dtype=jnp.int32)[None] + 1}
    a = engine.generate(prompt, max_new_tokens=6, key=jax.random.PRNGKey(1))
    b = engine.generate(prompt, max_new_tokens=6, key=jax.random.PRNGKey(2))
    np.testing.assert_array_equal(np.asarray(a.tokens), np.asarray(b.tokens))


def test_generation_temperature_varies():
    cfg, api, engine = _engine(temperature=2.0)
    prompt = {"tokens": jnp.arange(8, dtype=jnp.int32)[None] + 1}
    a = engine.generate(prompt, max_new_tokens=8, key=jax.random.PRNGKey(1))
    b = engine.generate(prompt, max_new_tokens=8, key=jax.random.PRNGKey(2))
    assert not np.array_equal(np.asarray(a.tokens), np.asarray(b.tokens))


def test_logprobs_are_valid():
    cfg, api, engine = _engine()
    prompt = {"tokens": jnp.arange(8, dtype=jnp.int32)[None] + 1}
    res = engine.generate(prompt, max_new_tokens=4)
    lp = np.asarray(res.logprobs)
    assert (lp <= 1e-5).all() and np.isfinite(lp).all()


def test_generated_tokens_within_true_vocab():
    """Vocab padding must never leak padded ids into generation."""
    cfg = dataclasses.replace(get_config("hymba_1_5b").reduced(),
                              vocab_size=1000)  # padded to 1024
    api = build_model(cfg, remat=False)
    params = api.init(jax.random.PRNGKey(0))
    engine = ServeEngine(api, params, temperature=1.5)
    prompt = {"tokens": jnp.arange(6, dtype=jnp.int32)[None] + 1}
    res = engine.generate(prompt, max_new_tokens=16, key=jax.random.PRNGKey(3))
    assert int(res.tokens.max()) < cfg.vocab_size


@pytest.mark.parametrize("arch", ["rwkv6_7b", "granite_moe_1b_a400m"])
def test_generate_state_archs(arch):
    cfg, api, engine = _engine(arch)
    prompt = {"tokens": jnp.arange(6, dtype=jnp.int32)[None] + 1}
    res = engine.generate(prompt, max_new_tokens=4)
    assert res.tokens.shape == (1, 4)


def test_checkpoint_restores_into_different_dtype_layout(tmp_path):
    """Save f32 training params; restore into the serving (bf16) layout by
    casting — the deployment path."""
    cfg = get_config("smollm_360m").reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    f = save_checkpoint(str(tmp_path), params, 1)
    like = jax.tree.map(np.zeros_like, jax.device_get(params))
    restored = restore_checkpoint(f, like)
    serving = jax.tree.map(
        lambda x: x.astype(jnp.bfloat16) if x.dtype == jnp.float32 else x,
        restored)
    api_bf16 = build_model(dataclasses.replace(cfg, param_dtype="bfloat16"),
                           remat=False)
    batch = {"tokens": jnp.arange(8, dtype=jnp.int32)[None],
             "labels": jnp.arange(8, dtype=jnp.int32)[None]}
    loss, _ = api_bf16.loss_fn(serving, batch)
    assert jnp.isfinite(loss)


def test_prefill_capacity_headroom():
    """Generation beyond the prefill length uses cache headroom correctly."""
    cfg, api, engine = _engine()
    prompt = {"tokens": jnp.arange(4, dtype=jnp.int32)[None] + 1}
    res = engine.generate(prompt, max_new_tokens=12, capacity=32)
    assert res.tokens.shape == (1, 12)
    assert np.isfinite(np.asarray(res.logprobs)).all()
