"""Serving engine: sampling modes, capacity handling, multi-arch generation,
and checkpoint resharding across plan changes."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.models import build_model
from repro.serve.engine import ServeEngine


def _engine(arch="llama3_2_1b", temperature=0.0, seed=0):
    cfg = get_config(arch).reduced()
    api = build_model(cfg, remat=False)
    params = api.init(jax.random.PRNGKey(seed))
    return cfg, api, ServeEngine(api, params, temperature=temperature)


def test_generation_deterministic_greedy():
    cfg, api, engine = _engine()
    prompt = {"tokens": jnp.arange(8, dtype=jnp.int32)[None] + 1}
    a = engine.generate(prompt, max_new_tokens=6, key=jax.random.PRNGKey(1))
    b = engine.generate(prompt, max_new_tokens=6, key=jax.random.PRNGKey(2))
    np.testing.assert_array_equal(np.asarray(a.tokens), np.asarray(b.tokens))


def test_generation_temperature_varies():
    cfg, api, engine = _engine(temperature=2.0)
    prompt = {"tokens": jnp.arange(8, dtype=jnp.int32)[None] + 1}
    a = engine.generate(prompt, max_new_tokens=8, key=jax.random.PRNGKey(1))
    b = engine.generate(prompt, max_new_tokens=8, key=jax.random.PRNGKey(2))
    assert not np.array_equal(np.asarray(a.tokens), np.asarray(b.tokens))


def test_logprobs_are_valid():
    cfg, api, engine = _engine()
    prompt = {"tokens": jnp.arange(8, dtype=jnp.int32)[None] + 1}
    res = engine.generate(prompt, max_new_tokens=4)
    lp = np.asarray(res.logprobs)
    assert (lp <= 1e-5).all() and np.isfinite(lp).all()


def test_generated_tokens_within_true_vocab():
    """Vocab padding must never leak padded ids into generation."""
    cfg = dataclasses.replace(get_config("hymba_1_5b").reduced(),
                              vocab_size=1000)  # padded to 1024
    api = build_model(cfg, remat=False)
    params = api.init(jax.random.PRNGKey(0))
    engine = ServeEngine(api, params, temperature=1.5)
    prompt = {"tokens": jnp.arange(6, dtype=jnp.int32)[None] + 1}
    res = engine.generate(prompt, max_new_tokens=16, key=jax.random.PRNGKey(3))
    assert int(res.tokens.max()) < cfg.vocab_size


@pytest.mark.parametrize("arch", ["rwkv6_7b", "granite_moe_1b_a400m"])
def test_generate_state_archs(arch):
    cfg, api, engine = _engine(arch)
    prompt = {"tokens": jnp.arange(6, dtype=jnp.int32)[None] + 1}
    res = engine.generate(prompt, max_new_tokens=4)
    assert res.tokens.shape == (1, 4)


def test_checkpoint_restores_into_different_dtype_layout(tmp_path):
    """Save f32 training params; restore into the serving (bf16) layout by
    casting — the deployment path."""
    cfg = get_config("smollm_360m").reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    f = save_checkpoint(str(tmp_path), params, 1)
    like = jax.tree.map(np.zeros_like, jax.device_get(params))
    restored = restore_checkpoint(f, like)
    serving = jax.tree.map(
        lambda x: x.astype(jnp.bfloat16) if x.dtype == jnp.float32 else x,
        restored)
    api_bf16 = build_model(dataclasses.replace(cfg, param_dtype="bfloat16"),
                           remat=False)
    batch = {"tokens": jnp.arange(8, dtype=jnp.int32)[None],
             "labels": jnp.arange(8, dtype=jnp.int32)[None]}
    loss, _ = api_bf16.loss_fn(serving, batch)
    assert jnp.isfinite(loss)


def test_prefill_capacity_headroom():
    """Generation beyond the prefill length uses cache headroom correctly."""
    cfg, api, engine = _engine()
    prompt = {"tokens": jnp.arange(4, dtype=jnp.int32)[None] + 1}
    res = engine.generate(prompt, max_new_tokens=12, capacity=32)
    assert res.tokens.shape == (1, 12)
    assert np.isfinite(np.asarray(res.logprobs)).all()


def test_undersized_capacity_rejected():
    """Regression: an explicit capacity too small for prompt + max_new used
    to silently overflow the KV cache — and capacity=0 was treated as
    'unset' by the old ``capacity or (...)`` default."""
    cfg, api, engine = _engine()
    prompt = {"tokens": jnp.arange(4, dtype=jnp.int32)[None] + 1}
    with pytest.raises(ValueError, match="capacity"):
        engine.generate(prompt, max_new_tokens=12, capacity=8)
    with pytest.raises(ValueError, match="capacity"):
        engine.generate(prompt, max_new_tokens=12, capacity=0)


def test_eos_stops_generation_and_reports_lengths():
    """Regression: generate had no EOS support — every request burned all
    max_new_tokens and returned post-EOS garbage."""
    cfg, api, engine = _engine()
    prompt = {"tokens": jnp.arange(8, dtype=jnp.int32)[None] + 1}
    ref = engine.generate(prompt, max_new_tokens=6)
    first = int(np.asarray(ref.tokens)[0, 0])
    res = engine.generate(prompt, max_new_tokens=6, eos_id=first)
    toks = np.asarray(res.tokens)[0]
    assert res.lengths is not None and int(res.lengths[0]) == 1
    assert (toks == first).all()            # stop token, then pad (= eos)
    assert np.asarray(res.logprobs)[0, 1:].sum() == 0.0  # frozen rows: lp 0

    # stop_tokens spelling, and un-hit stops leave generation untouched
    res2 = engine.generate(prompt, max_new_tokens=6, stop_tokens=(first,))
    assert int(res2.lengths[0]) == 1
    unseen = next(t for t in range(cfg.vocab_size)
                  if t not in set(np.asarray(ref.tokens)[0].tolist()))
    miss = engine.generate(prompt, max_new_tokens=6, eos_id=unseen)
    assert int(miss.lengths[0]) == 6
    np.testing.assert_array_equal(np.asarray(miss.tokens),
                                  np.asarray(ref.tokens))


def test_ragged_batch_matches_single_request():
    """Regression: the first token was sampled from ``logits[:, -1]`` — a
    PAD position for every row shorter than the batch max.  With
    ``prompt_lens`` each row gathers its own len-1 logits and decodes from
    its own cache position, bit-identical to running it alone."""
    cfg, api, engine = _engine()
    short = jnp.arange(3, dtype=jnp.int32)[None] + 7          # true prompt
    long = jnp.arange(5, dtype=jnp.int32)[None] + 1
    # left-aligned ragged batch: row 1 padded with a token that would skew
    # logits[:, -1] if it leaked in
    ragged = jnp.concatenate(
        [long, jnp.concatenate([short, jnp.full((1, 2), 99, jnp.int32)], 1)])
    res = engine.generate({"tokens": ragged}, max_new_tokens=6, capacity=32,
                          prompt_lens=jnp.array([5, 3], jnp.int32))
    solo_long = engine.generate({"tokens": long}, max_new_tokens=6,
                                capacity=32)
    solo_short = engine.generate({"tokens": short}, max_new_tokens=6,
                                 capacity=32)
    np.testing.assert_array_equal(np.asarray(res.tokens)[0],
                                  np.asarray(solo_long.tokens)[0])
    np.testing.assert_array_equal(np.asarray(res.tokens)[1],
                                  np.asarray(solo_short.tokens)[0])

    with pytest.raises(ValueError, match="prompt_lens"):
        engine.generate({"tokens": ragged}, max_new_tokens=2,
                        prompt_lens=jnp.array([5, 9], jnp.int32))


def test_keyless_temperature_sampling_differs_across_calls():
    """Regression: the default key was a fixed PRNGKey(0), so keyless
    temperature calls were bit-identical.  The engine now folds a call
    counter into its seed; explicit keys stay reproducible."""
    cfg, api, engine = _engine(temperature=2.0)
    prompt = {"tokens": jnp.arange(8, dtype=jnp.int32)[None] + 1}
    a = engine.generate(prompt, max_new_tokens=8)
    b = engine.generate(prompt, max_new_tokens=8)
    assert not np.array_equal(np.asarray(a.tokens), np.asarray(b.tokens))
    k = jax.random.PRNGKey(5)
    c = engine.generate(prompt, max_new_tokens=8, key=k)
    d = engine.generate(prompt, max_new_tokens=8, key=k)
    np.testing.assert_array_equal(np.asarray(c.tokens), np.asarray(d.tokens))
