"""Continuous-batching engine: slot/admission invariants and the TP decode
tick.

The load-bearing pins:
- a request admitted MID-FLIGHT produces tokens/logprobs bit-identical to
  running it alone (fixed-shape slotted cache + (rid, n_gen)-addressed
  sampling keys — batch composition can never leak into a request);
- chunked prefill is bit-equal to one-shot prefill (causal-within-chunk
  slot-mode extend);
- slots are evicted and reused across more requests than slots;
- the dp x tp decode tick (``transformer.decode_slots_tp``) is
  token-identical to single-device decode and its compiled HLO carries NO
  monolithic all-gather / all-reduce — only the chunk-sized collective
  permutes of the ppermute rings.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serve import ContinuousEngine, Request, ServeEngine

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_subprocess(code: str):
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


def _setup(arch="llama3_2_1b", seed=0):
    cfg = get_config(arch).reduced()
    api = build_model(cfg, remat=False)
    params = api.init(jax.random.PRNGKey(seed))
    return cfg, api, params


def _solo(api, params, prompt, max_new, **kw):
    eng = ContinuousEngine(api, params, n_slots=2, capacity=32, **kw)
    return eng.run([Request(rid=0, tokens=prompt, max_new_tokens=max_new)])[0]


def test_midflight_join_bit_identical():
    """A request joining a busy batch gets exactly its solo tokens AND the
    in-flight request it joined is not perturbed."""
    cfg, api, params = _setup()
    p0, p1 = list(range(1, 6)), list(range(7, 10))
    solo0 = _solo(api, params, p0, 6)
    solo1 = _solo(api, params, p1, 6)

    eng = ContinuousEngine(api, params, n_slots=2, capacity=32)
    eng.submit(Request(rid=0, tokens=p0, max_new_tokens=6))
    for _ in range(3):                      # r0 is mid-decode...
        eng.step()
    eng.submit(Request(rid=1, tokens=p1, max_new_tokens=6))   # ...r1 joins
    while eng.step():
        pass
    res = {r.rid: r for r in eng.results}
    assert res[0].tokens == solo0.tokens
    assert res[0].logprobs == solo0.logprobs
    assert res[1].tokens == solo1.tokens
    assert res[1].logprobs == solo1.logprobs


def test_chunked_prefill_equals_one_shot():
    cfg, api, params = _setup()
    prompt = list(range(1, 8))
    one_shot = _solo(api, params, prompt, 5)
    for chunk in (1, 3):
        chunked = _solo(api, params, prompt, 5, prefill_chunk=chunk)
        assert chunked.tokens == one_shot.tokens, chunk
        # logprobs agree to fp rounding only: the chunk's valid keys sit at
        # different indices of the attention axis, reordering the summation
        np.testing.assert_allclose(chunked.logprobs, one_shot.logprobs,
                                   rtol=1e-5, atol=1e-5)


def test_slot_eviction_and_reuse():
    """More requests than slots: every slot is evicted and re-admitted, and
    each request still matches its solo run."""
    cfg, api, params = _setup()
    eng = ContinuousEngine(api, params, n_slots=2, capacity=32)
    prompts = [[1 + i, 2 + i, 3 + i] for i in range(4)]
    out = eng.run([Request(rid=i, tokens=p, max_new_tokens=4)
                   for i, p in enumerate(prompts)])
    assert [r.rid for r in out] == [0, 1, 2, 3]
    assert all(r.finished_reason == "length" for r in out)
    for i, r in enumerate(out):
        assert r.tokens == _solo(api, params, prompts[i], 4).tokens, i


def test_matches_static_engine_greedy():
    cfg, api, params = _setup()
    prompt = list(range(1, 6))
    res = _solo(api, params, prompt, 6)
    ref = ServeEngine(api, params).generate(
        {"tokens": jnp.asarray(prompt, jnp.int32)[None]}, max_new_tokens=6)
    assert res.tokens == [int(t) for t in np.asarray(ref.tokens)[0]]


def test_eos_finishes_and_frees_slot():
    """eos_id ends a request early (reason "eos") and its freed slot admits
    the queued request, which still matches its solo run."""
    cfg, api, params = _setup()
    p = list(range(1, 6))
    first = _solo(api, params, p, 1).tokens[0]
    eng = ContinuousEngine(api, params, n_slots=1, capacity=32)
    out = eng.run([Request(rid=0, tokens=p, max_new_tokens=8, eos_id=first),
                   Request(rid=1, tokens=[9, 8, 7], max_new_tokens=3)])
    assert out[0].finished_reason == "eos"
    assert out[0].tokens == [first]
    assert out[1].tokens == _solo(api, params, [9, 8, 7], 3).tokens


def test_temperature_reproducible_and_batch_independent():
    """(rid, n_gen)-keyed sampling: same seed reproduces, and a request's
    sampled stream does not depend on who shares the batch."""
    cfg, api, params = _setup()
    p0, p1 = list(range(1, 6)), list(range(7, 10))
    a = _solo(api, params, p0, 6, temperature=1.0, seed=3)
    b = _solo(api, params, p0, 6, temperature=1.0, seed=3)
    assert a.tokens == b.tokens
    eng = ContinuousEngine(api, params, n_slots=2, capacity=32,
                           temperature=1.0, seed=3)
    out = eng.run([Request(rid=0, tokens=p0, max_new_tokens=6),
                   Request(rid=1, tokens=p1, max_new_tokens=6)])
    assert out[0].tokens == a.tokens


def test_slot_capacity_overflow_rejected():
    cfg, api, params = _setup()
    eng = ContinuousEngine(api, params, n_slots=2, capacity=8)
    with pytest.raises(ValueError, match="exceeds slot capacity"):
        eng.submit(Request(rid=0, tokens=list(range(6)), max_new_tokens=4))


def test_deadline_evicts_stalled_request_and_frees_admission():
    """Per-request TTL: a long request monopolizing the only slot is evicted
    at its deadline with its partial tokens flagged "timed_out", and the
    starved queued request then admits — and still matches its solo run."""
    cfg, api, params = _setup()
    now = [0.0]
    eng = ContinuousEngine(api, params, n_slots=1, capacity=64,
                           clock=lambda: now[0])
    eng.submit(Request(rid=0, tokens=[1, 2, 3], max_new_tokens=50,
                       deadline_s=5.0))
    eng.submit(Request(rid=1, tokens=[9, 8, 7], max_new_tokens=3))
    for _ in range(4):
        eng.step()                     # r0 decodes, r1 starves in the queue
    assert not any(r.rid == 1 for r in eng.results)
    now[0] = 10.0                      # r0's deadline passes
    while eng.step():
        pass
    res = {r.rid: r for r in eng.results}
    assert res[0].finished_reason == "timed_out"
    assert 0 < len(res[0].tokens) < 50          # partial output preserved
    assert res[0].logprobs and len(res[0].logprobs) == len(res[0].tokens)
    assert res[1].finished_reason == "length"
    assert res[1].tokens == _solo(api, params, [9, 8, 7], 3).tokens


def test_deadline_expires_queued_request_without_admission():
    """A request whose TTL lapses while still queued never takes a slot: it
    returns empty, flagged "timed_out", and in-flight work is unaffected."""
    cfg, api, params = _setup()
    now = [0.0]
    eng = ContinuousEngine(api, params, n_slots=1, capacity=32,
                           clock=lambda: now[0])
    eng.submit(Request(rid=0, tokens=[1, 2, 3], max_new_tokens=4))
    eng.step()                          # r0 holds the slot
    eng.submit(Request(rid=1, tokens=[4, 5], max_new_tokens=2,
                       deadline_s=1.0))
    now[0] = 2.0                        # r1 expires before a slot frees
    while eng.step():
        pass
    res = {r.rid: r for r in eng.results}
    assert res[1].finished_reason == "timed_out"
    assert res[1].tokens == [] and res[1].logprobs == []
    assert res[0].finished_reason == "length"
    assert res[0].tokens == _solo(api, params, [1, 2, 3], 4).tokens


def test_state_arch_rejected_with_shaped_error():
    cfg, api, params = _setup("rwkv6_7b")
    with pytest.raises(ValueError, match="slotted KV serving"):
        ContinuousEngine(api, params, n_slots=2, capacity=32)


def test_tp_decode_matches_single_device_and_hlo_is_ring_only():
    """The tentpole pin: the dp x tp continuous engine produces exactly the
    single-device tokens, and the compiled decode-tick HLO contains only
    collective-permutes (the chunked rings) — zero monolithic all-gather /
    all-reduce."""
    out = _run_subprocess("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import build_model
        from repro.models import transformer as tf_mod
        from repro.models.api import make_slot_cache
        from repro.parallel.jaxcompat import make_mesh
        from repro.serve import ContinuousEngine, Request
        from repro.core.roofline import parse_collectives

        cfg = get_config("llama3_2_1b").reduced()
        api = build_model(cfg, remat=False)
        params = api.init(jax.random.PRNGKey(0))
        mesh = make_mesh((1, 2), ("data", "model"))
        assert tf_mod.decode_slots_tp_supported(cfg, mesh, "model",
                                                ("data",), 4)

        reqs = lambda: [
            Request(rid=0, tokens=list(range(1, 6)), max_new_tokens=6),
            Request(rid=1, tokens=list(range(7, 10)), max_new_tokens=6)]
        ref = ContinuousEngine(api, params, n_slots=4, capacity=32).run(reqs())
        tp = ContinuousEngine(api, params, n_slots=4, capacity=32,
                              mesh=mesh, model_axis="model",
                              batch_axes=("data",)).run(reqs())
        for a, b in zip(ref, tp):
            assert a.tokens == b.tokens, (a.tokens, b.tokens)
            np.testing.assert_allclose(a.logprobs, b.logprobs,
                                       rtol=2e-4, atol=2e-4)

        # HLO: only ring permutes on the decode tick
        sc = make_slot_cache(cfg, 4, 32)
        tok = jnp.zeros((4, 1), jnp.int32)
        tf_mod.L.set_analysis_unroll(True)
        try:
            hlo = (jax.jit(lambda p, c, b: tf_mod.decode_slots_tp(
                       cfg, p, c, b, mesh=mesh, model_axis="model",
                       batch_axes=("data",)))
                   .lower(params, sc, {"tokens": tok}).compile().as_text())
        finally:
            tf_mod.L.set_analysis_unroll(False)
        st = parse_collectives(hlo, 2)
        assert st.ops.get("collective-permute", 0) >= 2 * cfg.n_layers, st.ops
        mono = {k: v for k, v in st.ops.items()
                if k in ("all-gather", "all-reduce") and v}
        assert not mono, (mono, st.ops)
        print("TP_OK", st.ops)
    """)
    assert "TP_OK" in out


def test_duplicate_inflight_rid_rejected():
    """rids key deadlines and results: a duplicate in-flight rid raises a
    shaped error instead of silently corrupting the first request's
    accounting — both while queued and while holding a slot."""
    cfg, api, params = _setup()
    eng = ContinuousEngine(api, params, n_slots=1, capacity=32)
    eng.submit(Request(rid=3, tokens=[1, 2], max_new_tokens=4))
    with pytest.raises(ValueError, match="rid 3 is already in flight"):
        eng.submit(Request(rid=3, tokens=[5, 6], max_new_tokens=4))  # queued
    eng.step()
    with pytest.raises(ValueError, match="rid 3 is already in flight"):
        eng.submit(Request(rid=3, tokens=[5, 6], max_new_tokens=4))  # active
    while eng.step():
        pass
    # the rid is reusable once its result is out
    assert eng.submit(Request(rid=3, tokens=[5, 6], max_new_tokens=2)) is None
    while eng.step():
        pass
    assert sum(r.rid == 3 for r in eng.results) == 2


def test_max_queue_overflow_sheds_with_shaped_result():
    """Bounded admission: queue overflow is rejected with a shaped
    finished_reason="shed" result (returned AND appended to results) rather
    than growing the backlog without bound; admitted work is unaffected."""
    cfg, api, params = _setup()
    eng = ContinuousEngine(api, params, n_slots=1, capacity=32, max_queue=1)
    assert eng.submit(Request(rid=0, tokens=[1, 2], max_new_tokens=3)) is None
    shed = eng.submit(Request(rid=1, tokens=[3, 4], max_new_tokens=3))
    assert shed is not None and shed.finished_reason == "shed"
    assert shed.tokens == [] and shed.rid == 1
    while eng.step():
        pass
    res = {r.rid: r for r in eng.results}
    assert set(res) == {0, 1}
    assert res[1].finished_reason == "shed"
    assert res[0].tokens == _solo(api, params, [1, 2], 3).tokens


def test_replay_resume_bit_identical():
    """The failover primitive: re-prefilling the PROMPT and replaying the
    already-generated tokens through decode ticks reconstructs the original
    computation — the continuation is bit-identical (tokens AND logprob
    bits) to the uninterrupted run."""
    cfg, api, params = _setup()
    prompt = list(range(1, 6))
    solo = _solo(api, params, prompt, 8)
    for k in (1, 4):
        eng = ContinuousEngine(api, params, n_slots=2, capacity=32)
        res = eng.run([Request(
            rid=0, tokens=prompt, max_new_tokens=8,
            replay_tokens=tuple(solo.tokens[:k]),
            replay_logprobs=tuple(solo.logprobs[:k]))])[0]
        assert res.tokens == solo.tokens, k
        assert res.logprobs == solo.logprobs, k
    eng = ContinuousEngine(api, params, n_slots=2, capacity=32)
    with pytest.raises(ValueError, match="one logprob per replayed token"):
        eng.submit(Request(rid=0, tokens=prompt, max_new_tokens=8,
                           replay_tokens=(1, 2), replay_logprobs=(0.0,)))
    with pytest.raises(ValueError, match="exceed max_new_tokens"):
        eng.submit(Request(rid=0, tokens=prompt, max_new_tokens=1,
                           replay_tokens=(1, 2),
                           replay_logprobs=(0.0, 0.0)))


def test_nondivisible_prefill_chunk_uses_sharded_padded_path():
    """Non-divisible final prefill chunks run the SAME sharded TP path,
    padded up to the ring grid with ``n_valid`` masking (no single-device
    fallback): token parity with one-shot single-device prefill, and the
    pad-slack capacity guard rejects prompts whose pad rows overflow."""
    out = _run_subprocess("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        import jax, numpy as np
        from repro.configs import get_config
        from repro.models import build_model
        from repro.parallel.jaxcompat import make_mesh
        from repro.serve import ContinuousEngine, Request

        cfg = get_config("llama3_2_1b").reduced()
        api = build_model(cfg, remat=False)
        params = api.init(jax.random.PRNGKey(0))
        mesh = make_mesh((1, 2), ("data", "model"))

        # prompt 7 with chunk 3 -> chunks 3,3,1: none divide the tp grid
        req = lambda: [Request(rid=0, tokens=list(range(1, 8)),
                               max_new_tokens=5)]
        ref = ContinuousEngine(api, params, n_slots=2, capacity=32).run(req())
        eng = ContinuousEngine(api, params, n_slots=2, capacity=32,
                               prefill_chunk=3, mesh=mesh,
                               model_axis="model", batch_axes=("data",))
        assert eng._prefill_grid == 2, eng._prefill_grid   # sharded, padded
        tp = eng.run(req())
        assert tp[0].tokens == ref[0].tokens, (tp[0].tokens, ref[0].tokens)
        np.testing.assert_allclose(tp[0].logprobs, ref[0].logprobs,
                                   rtol=2e-4, atol=2e-4)

        # pad-slack guard: a full-capacity odd prompt's pad row overflows
        tight = ContinuousEngine(api, params, n_slots=2, capacity=7,
                                 mesh=mesh, model_axis="model",
                                 batch_axes=("data",))
        try:
            tight.submit(Request(rid=1, tokens=list(range(1, 8)),
                                 max_new_tokens=0))
        except ValueError as e:
            assert "sharded-prefill pad" in str(e), e
        else:
            raise AssertionError("pad overflow accepted")
        print("PAD_OK")
    """)
    assert "PAD_OK" in out
