"""Multi-replica router: health-checked failover, shedding, elasticity.

The load-bearing pins (the PR's acceptance criteria):
- a request whose replica is KILLED mid-decode completes on another
  replica with tokens AND logprobs bit-identical to the same request on
  an unfaulted single-replica run (shared engine seed + (rid, n_gen)-
  addressed sampling keys + replay-based re-prefill — see
  ``serve/router.py``'s failover state machine);
- the same bit-equality when the replica STALLS past the watchdog or
  emits NaN logprobs (``nanlogits``; the poisoned suffix is discarded and
  regenerated, never delivered);
- exact accounting: every submitted rid appears in ``results`` exactly
  once — completed, shed (projected wait / bounded queue), or timed out;
- deadline-aware retry: a failover whose backoff cannot beat the deadline
  times out instead of wasting a dispatch;
- elastic drain/grow mirrors PR 7's elastic DP: a draining replica
  finishes its work, is removed, and a grown replica serves bit-identical
  continuations.

Prompts within a test share one length: a new prompt length retraces the
jitted prefill (seconds of XLA compile), which the armed watchdog would
flag as a stall.
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serve import ContinuousEngine, ReplicaRouter, Request
from repro.train.fault import Fault, parse_fault_schedule

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_subprocess(code: str):
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


def _setup(seed=0):
    cfg = get_config("llama3_2_1b").reduced()
    api = build_model(cfg, remat=False)
    params = api.init(jax.random.PRNGKey(seed))
    return cfg, api, params


def _reqs(n=4, max_new=6, **kw):
    return [Request(rid=i, tokens=[1 + i, 2 + i, 3 + i, 4 + i],
                    max_new_tokens=max_new, **kw) for i in range(n)]


def _solo_ref(api, params, n=4, max_new=6):
    """Unfaulted single-replica reference (same seed as every router
    replica): the bitwise target for all failover paths."""
    eng = ContinuousEngine(api, params, n_slots=2, capacity=32)
    return {r.rid: r for r in eng.run(_reqs(n, max_new))}


def _assert_bit_equal(results, ref):
    assert sorted(r.rid for r in results) == sorted(ref)
    for r in results:
        assert r.tokens == ref[r.rid].tokens, r.rid
        assert r.logprobs == ref[r.rid].logprobs, r.rid
        assert r.finished_reason in ("eos", "length")


def test_no_fault_router_matches_solo_and_accounts_every_rid():
    cfg, api, params = _setup()
    ref = _solo_ref(api, params)
    rt = ReplicaRouter(api, params, replicas=2, n_slots=2, capacity=32)
    out = rt.run(_reqs())
    _assert_bit_equal(out, ref)
    assert rt.stats == {"completed": 4, "shed": 0, "timed_out": 0,
                        "failovers": 0}
    assert rt.replica_states == ["healthy", "healthy"]


def test_kill_midflight_failover_bit_identical():
    """THE acceptance pin: replica 0 dies with requests mid-decode; they
    complete on replica 1 bit-identical to the unfaulted run."""
    cfg, api, params = _setup()
    ref = _solo_ref(api, params)
    rt = ReplicaRouter(api, params, replicas=2, n_slots=2, capacity=32,
                       faults=parse_fault_schedule("kill@3:0"),
                       retry_backoff_s=0.0)
    for r in _reqs():
        rt.submit(r)
    rt.step()
    rt.step()
    # genuinely mid-decode: replica 0's requests have generated tokens
    pre = {rid: list(tr.tokens) for rid, tr in rt.tracked.items()
           if tr.replica == 0}
    assert pre and all(len(t) > 0 for t in pre.values())
    rt.step()                              # tick 3: the kill fires
    assert rt.replica_states[0] == "dead"
    assert rt.fault_log == [("kill", 3, 0)]
    assert rt.stats["failovers"] == len(pre)
    while rt.step():
        pass
    _assert_bit_equal(sorted(rt.results, key=lambda r: r.rid), ref)
    assert rt.stats["completed"] == 4 and rt.stats["timed_out"] == 0


def test_stall_past_watchdog_failover_bit_identical():
    """A replica hanging past the watchdog is degraded (heartbeat reuse of
    ``train.fault.Watchdog``) and its requests fail over bit-identically;
    the stalled tick's own output is still valid (detection-only)."""
    cfg, api, params = _setup()
    ref = _solo_ref(api, params)
    rt = ReplicaRouter(api, params, replicas=2, n_slots=2, capacity=32,
                       faults=parse_fault_schedule("stall@3:0:0.5"),
                       watchdog_timeout_s=0.15, retry_backoff_s=0.0)
    out = rt.run(_reqs())
    rt.close()
    assert rt.replica_states == ["degraded", "healthy"]
    assert ("stall", 3, 0) in rt.fault_log
    assert rt.stats["failovers"] > 0
    _assert_bit_equal(out, ref)


def test_nanlogits_degrades_replica_and_regenerates_poisoned_suffix():
    """NaN-logit health check: the poisoned replica is quarantined, the
    non-finite suffix is never delivered, and the re-generated
    continuation is bit-identical to the unfaulted run."""
    cfg, api, params = _setup()
    ref = _solo_ref(api, params)
    rt = ReplicaRouter(api, params, replicas=2, n_slots=2, capacity=32,
                       faults=parse_fault_schedule("nanlogits@2:1"),
                       retry_backoff_s=0.0)
    out = rt.run(_reqs())
    assert rt.replica_states == ["healthy", "degraded"]
    assert all(np.isfinite(lp) for r in out for lp in r.logprobs)
    _assert_bit_equal(out, ref)


def test_projected_wait_and_bounded_queue_shed_exactly_once():
    """Load shedding both ways — projected wait > deadline at the door,
    and per-engine ``max_queue`` overflow — with every rid accounted."""
    cfg, api, params = _setup()
    # projected-wait: the EWMA step estimate prices the backlog out
    rt = ReplicaRouter(api, params, replicas=1, n_slots=1, capacity=32,
                       est_step_s=10.0)
    assert rt.submit(Request(rid=0, tokens=[1, 2, 3],
                             max_new_tokens=4)) is None
    shed = rt.submit(Request(rid=1, tokens=[1, 2, 3], max_new_tokens=4,
                             deadline_s=1.0))
    assert shed is not None and shed.finished_reason == "shed"
    while rt.step():
        pass
    assert sorted(r.rid for r in rt.results) == [0, 1]
    assert rt.stats["shed"] == 1 and rt.stats["completed"] == 1

    # bounded queue: the engine's max_queue rejection surfaces as a
    # router shed with router-side accounting (no double count)
    rt2 = ReplicaRouter(api, params, replicas=1, n_slots=1, capacity=32,
                        max_queue=1)
    rt2.submit(Request(rid=0, tokens=[1, 2], max_new_tokens=2))
    rt2.submit(Request(rid=1, tokens=[1, 2], max_new_tokens=2))
    shed2 = rt2.submit(Request(rid=2, tokens=[1, 2], max_new_tokens=2))
    assert shed2 is not None and shed2.finished_reason == "shed"
    while rt2.step():
        pass
    assert sorted(r.rid for r in rt2.results) == [0, 1, 2]
    assert sum(r.finished_reason == "shed" for r in rt2.results) == 2


def test_deadline_aware_retry_times_out_instead_of_wasted_dispatch():
    """A failover whose capped backoff cannot beat the request deadline is
    finalized "timed_out" immediately — no pointless re-dispatch."""
    cfg, api, params = _setup()
    rt = ReplicaRouter(api, params, replicas=2, n_slots=2, capacity=32,
                       faults=parse_fault_schedule("kill@2:0"),
                       retry_backoff_s=100.0, max_retry_backoff_s=100.0,
                       clock=lambda: 0.0)
    for r in _reqs(n=4, max_new=6, deadline_s=5.0):
        rt.submit(r)
    while rt.step():
        pass
    res = {r.rid: r for r in rt.results}
    assert sorted(res) == [0, 1, 2, 3]
    reasons = {r.finished_reason for r in res.values()}
    assert "timed_out" in reasons            # replica 0's requests
    assert rt.stats["timed_out"] == rt.stats["failovers"] > 0


def test_drain_and_grow_bit_identical():
    """Elastic shrink/grow: a draining replica finishes its in-flight work
    and is removed; a grown replica (same seed) serves new dispatches with
    unchanged results."""
    cfg, api, params = _setup()
    ref = _solo_ref(api, params, n=6)
    rt = ReplicaRouter(api, params, replicas=2, n_slots=2, capacity=32)
    reqs = _reqs(n=6)
    for r in reqs[:4]:
        rt.submit(r)
    rt.step()
    rt.drain_replica(0)
    assert rt.add_replica() == 2
    for r in reqs[4:]:                     # lands on the grown replica
        rt.submit(r)
    assert any(tr.replica == 2 for tr in rt.tracked.values())
    while rt.step():
        pass
    assert rt.replica_states == ["removed", "healthy", "healthy"]
    _assert_bit_equal(sorted(rt.results, key=lambda r: r.rid), ref)


def test_router_rejects_training_form_faults_and_duplicate_rids():
    cfg, api, params = _setup()
    with pytest.raises(ValueError, match="replica-keyed"):
        ReplicaRouter(api, params, replicas=1, n_slots=1, capacity=32,
                      faults=[Fault("kill", 3)])      # no replica
    with pytest.raises(ValueError, match="replica-keyed"):
        ReplicaRouter(api, params, replicas=1, n_slots=1, capacity=32,
                      faults=parse_fault_schedule("fail@3"))
    rt = ReplicaRouter(api, params, replicas=1, n_slots=2, capacity=32)
    rt.submit(Request(rid=7, tokens=[1, 2], max_new_tokens=2))
    with pytest.raises(ValueError, match="already in flight"):
        rt.submit(Request(rid=7, tokens=[3, 4], max_new_tokens=2))


def test_from_choice_executes_replicas_axis():
    """``InferenceChoice.build_router`` finally executes the planner's
    ``replicas`` axis (ROADMAP open item 1): the constructed router has
    one engine group per planned replica and serves bit-identically."""
    from repro.core.planner import InferenceChoice
    from repro.parallel.plan import serve_plan

    cfg, api, params = _setup()
    ref = _solo_ref(api, params)
    choice = InferenceChoice(replicas=2, tp=1, slots=2, step_latency=1e-3,
                             tokens_per_s=1.0, mem_bytes=0.0,
                             mesh_shape=(2, 1), plan=serve_plan(1))
    rt = choice.build_router(api, params, capacity=32)
    assert len(rt.replicas) == choice.replicas
    assert all(r.engine.n_slots == choice.slots for r in rt.replicas)
    _assert_bit_equal(rt.run(_reqs()), ref)


@pytest.mark.slow
def test_from_choice_tp_replica_groups_kill_failover_subprocess():
    """replicas=2 x tp=2 on four forced host devices: each replica group
    gets a DISJOINT 2-device mesh, and a kill mid-decode still completes
    bit-identical to an unfaulted single TP group (same decode geometry,
    so even the logprob bits match)."""
    out = _run_subprocess("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax
        from repro.configs import get_config
        from repro.core.planner import InferenceChoice
        from repro.models import build_model
        from repro.parallel.plan import serve_plan
        from repro.serve import ContinuousEngine, Request
        from repro.train.fault import parse_fault_schedule

        cfg = get_config("llama3_2_1b").reduced()
        api = build_model(cfg, remat=False)
        params = api.init(jax.random.PRNGKey(0))
        reqs = lambda: [Request(rid=i, tokens=[1+i, 2+i, 3+i, 4+i],
                                max_new_tokens=5) for i in range(4)]

        choice = InferenceChoice(replicas=2, tp=2, slots=2,
                                 step_latency=1e-3, tokens_per_s=1.0,
                                 mem_bytes=0.0, mesh_shape=(2, 2),
                                 plan=serve_plan(2))
        rt = choice.build_router(api, params, capacity=32,
                                 faults=parse_fault_schedule("kill@3:0"),
                                 retry_backoff_s=0.0)
        meshes = rt._meshes
        assert len(meshes) == 2
        d0 = {d.id for d in meshes[0].devices.flat}
        d1 = {d.id for d in meshes[1].devices.flat}
        assert d0 and d1 and not (d0 & d1), (d0, d1)   # disjoint groups

        out = rt.run(reqs())
        assert rt.replica_states[0] == "dead"

        # unfaulted single TP group with the same geometry and seed
        ref_eng = ContinuousEngine(api, params, n_slots=2, capacity=32,
                                   mesh=meshes[1], model_axis="model",
                                   batch_axes=("data",))
        ref = {r.rid: r for r in ref_eng.run(reqs())}
        for r in out:
            assert r.tokens == ref[r.rid].tokens, r.rid
            assert r.logprobs == ref[r.rid].logprobs, r.rid
        print("ROUTER_TP_OK", rt.stats)
    """)
    assert "ROUTER_TP_OK" in out
