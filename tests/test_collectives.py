"""Overlap-scheduled collective subsystem (ISSUE 5): chunked collective-matmul
ring primitives and their custom-vjp backward vs plain AD, the overlapped
transformer/LSTM tensor-MP paths vs the GSPMD reference at fp32 round-off
over the (chunks x mesh x arch) grid, the PR 2-style HLO assertion that the
overlapped matmul hot path carries no monolithic all-gather/all-reduce, and
the bucketed DP reduce-scatter gradient sync (bit-equal params, per-bucket
collective split in the compiled HLO)."""
import os
import subprocess
import sys
import textwrap
import warnings

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.parallel.collectives import grad_bucket_sizes
from repro.parallel.plan import ParallelPlan
from repro.parallel.sharding import ShardingRules

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_subprocess(code: str):
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


# ---------------------------------------------------------------------------
# pure (no-device) units
# ---------------------------------------------------------------------------

def test_grad_bucket_sizes_packing():
    """Reverse-traversal greedy packing: every bucket <= target unless a
    single oversized leaf owns it, all leaves covered exactly once."""
    grads = {"a": jnp.zeros((100,)), "b": jnp.zeros((10,)),
             "c": jnp.zeros((200,)), "d": jnp.zeros((5,))}
    sizes = grad_bucket_sizes(grads, bucket_bytes=480)  # 120 floats
    assert sum(sizes) == 4
    # reverse flatten order: d(5), c(200), b(10), a(100) — c overflows alone
    assert sizes == [1, 1, 2]
    # one giant bucket swallows everything
    assert grad_bucket_sizes(grads, bucket_bytes=1e9) == [4]
    # tiny target: one leaf per bucket
    assert grad_bucket_sizes(grads, bucket_bytes=1) == [1, 1, 1, 1]


def test_plan_comm_runtime_validation():
    assert ParallelPlan(comm_runtime="overlapped").comm_runtime == "overlapped"
    with pytest.raises(ValueError, match="comm runtime"):
        ParallelPlan(comm_runtime="nope")
    with pytest.raises(ValueError, match="comm_chunks"):
        ParallelPlan(comm_chunks=0)
    mesh_shape = {"data": 2, "model": 2}

    class FakeMesh:
        shape = mesh_shape
        axis_names = ("data", "model")

    desc = ParallelPlan(comm_runtime="overlapped",
                        comm_chunks=2).describe(FakeMesh())
    assert "overlapped comm c=2" in desc


def test_sharding_fallback_warns_once_per_rule():
    """ISSUE 5 satellite: the silent replication fallback on non-divisible
    dims (smollm's 15 heads on a 16-way axis) must emit a once-per-rule
    warning naming the param path and dim."""

    class FakeMesh:
        def __init__(self, shape):
            self.shape = shape
            self.axis_names = tuple(shape)

    cfg = get_config("smollm_360m")
    api = build_model(cfg)
    params_shape = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    rules = ShardingRules(cfg, FakeMesh({"data": 16, "model": 16}),
                          ParallelPlan())
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        rules.params_specs(params_shape)
        msgs = [str(x.message) for x in w if "[sharding]" in str(x.message)]
    assert msgs, "no fallback warning for smollm's 15 heads on 16-way MP"
    assert any("wq" in m and "15" in m and "16-way" in m for m in msgs), msgs
    # once per rule: re-walking the same tree must not re-warn
    with warnings.catch_warnings(record=True) as w2:
        warnings.simplefilter("always")
        rules.params_specs(params_shape)
        again = [str(x.message) for x in w2 if "[sharding]" in str(x.message)]
    assert not again, again
    # a divisible arch stays silent
    cfg_ok = get_config("llama3_2_1b")
    api_ok = build_model(cfg_ok)
    rules_ok = ShardingRules(cfg_ok, FakeMesh({"data": 16, "model": 16}),
                             ParallelPlan())
    with warnings.catch_warnings(record=True) as w3:
        warnings.simplefilter("always")
        rules_ok.params_specs(jax.eval_shape(api_ok.init,
                                             jax.random.PRNGKey(0)))
        bad = [str(x.message) for x in w3 if "[sharding]" in str(x.message)
               and "head" not in str(x.message)]
    assert not bad, bad


def test_overlapped_supported_gating():
    """The overlapped block only engages for homogeneous dense decoders with
    divisible heads/ffn/seq; everything else must fall back to GSPMD."""
    from repro.models.transformer import ParallelCtx, overlapped_supported

    class FakeMesh:
        def __init__(self, m):
            self.shape = {"data": 2, "model": m}

    def ctx(m, rt="overlapped", chunks=1):
        return ParallelCtx(mesh=FakeMesh(m), batch_axes=("data",),
                           model_axis="model", comm_runtime=rt,
                           comm_chunks=chunks)

    dense = get_config("llama3_2_1b").reduced()    # 4 heads, ff 512
    assert overlapped_supported(dense, ctx(2), t=32)
    assert overlapped_supported(dense, ctx(4), t=32)
    assert not overlapped_supported(dense, ctx(4, rt="gspmd"), t=32)
    assert not overlapped_supported(dense, ctx(1), t=32)
    assert not overlapped_supported(dense, ctx(4), t=30)   # seq % m
    assert not overlapped_supported(dense, ctx(8), t=32)   # heads % m
    assert not overlapped_supported(dense, ctx(4, chunks=3), t=32)
    assert not overlapped_supported(dense, None, t=32)
    moe = get_config("granite_moe_1b_a400m").reduced()
    assert not overlapped_supported(moe, ctx(2), t=32)
    rwkv = get_config("rwkv6_7b").reduced()
    assert not overlapped_supported(rwkv, ctx(2), t=32)


# ---------------------------------------------------------------------------
# multi-device equivalence (subprocesses)
# ---------------------------------------------------------------------------

def test_collective_matmul_primitives_match_reference():
    """all_gather_matmul / matmul_reduce_scatter forward AND custom-vjp
    backward vs plain jnp reference + AD, over the chunk sweep."""
    out = _run_subprocess("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.parallel.jaxcompat import make_mesh, set_mesh, shard_map
        from repro.parallel.collectives import (all_gather_matmul,
                                                matmul_reduce_scatter)

        m = 4
        mesh = make_mesh((1, m), ("data", "model"))
        key = jax.random.PRNGKey(0)
        B, T, D, F = 2, 16, 6, 12
        x = jax.random.normal(key, (B, T, D))
        w = jax.random.normal(jax.random.PRNGKey(1), (D, F)) * 0.3
        w2 = jax.random.normal(jax.random.PRNGKey(2), (F, D)) * 0.3

        def ref(x, w, w2):
            return ((jnp.tanh(x @ w) @ w2) ** 2).sum()

        lr, gr = jax.value_and_grad(ref, argnums=(0, 1, 2))(x, w, w2)
        for chunks in (1, 2, 4):
            def f(x, w, w2):
                def local(xl, wl, w2l):
                    h = all_gather_matmul(xl, wl, axis="model", axis_size=m,
                                          chunks=chunks)
                    return matmul_reduce_scatter(jnp.tanh(h), w2l,
                                                 axis="model", axis_size=m,
                                                 chunks=chunks)
                y = shard_map(local, mesh=mesh,
                              in_specs=(P(None, "model", None),
                                        P(None, "model"), P("model", None)),
                              out_specs=P(None, "model", None))(x, w, w2)
                return (y ** 2).sum()

            with set_mesh(mesh):
                l, g = jax.jit(jax.value_and_grad(f, argnums=(0, 1, 2)))(
                    x, w, w2)
            assert abs(float(l) - float(lr)) < 1e-4, (chunks, float(l),
                                                      float(lr))
            for a, b in zip(g, gr):
                err = float(jnp.abs(a - b).max())
                assert err < 1e-4, (chunks, err)
            print("OK", chunks)
    """)
    assert out.count("OK") == 3


@pytest.mark.parametrize("arch", ["llama3_2_1b", "stablelm_12b"])
def test_overlapped_transformer_matches_gspmd_grid(arch):
    """Acceptance: overlapped collective-matmul == GSPMD loss AND grads at
    fp32 round-off over the (chunks x mesh) grid, plus a non-divisible-KV
    variant exercising the replicated-KV slice path."""
    out = _run_subprocess(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import jax, jax.numpy as jnp
        from repro.parallel.jaxcompat import make_mesh, set_mesh
        from repro.configs import get_config
        from repro.models import build_model
        from repro.models.transformer import ParallelCtx
        from repro.parallel.plan import ParallelPlan
        from repro.parallel.sharding import ShardingRules

        cfgs = [get_config("{arch}").reduced()]
        # non-divisible KV: 4 q heads, 1 kv head on mp=2/4 (replicated KV)
        c = cfgs[0]
        if not c.is_moe:
            cfgs.append(dataclasses.replace(c, n_kv_heads=1))
        for cfg in cfgs:
            api = build_model(cfg, remat=False)
            key = jax.random.PRNGKey(0)
            params = api.init(key)
            batch = {{"tokens": jax.random.randint(key, (8, 32), 0,
                                 cfg.vocab_size, dtype=jnp.int32),
                      "labels": jax.random.randint(key, (8, 32), 0,
                                 cfg.vocab_size, dtype=jnp.int32)}}
            ref_l, ref_g = jax.value_and_grad(
                lambda p: api.loss_fn(p, batch)[0])(params)
            for dp, mp in ((2, 4), (4, 2)):
                for chunks in (1, 2):
                    mesh = make_mesh((dp, mp), ("data", "model"))
                    pctx = ParallelCtx(mesh=mesh, batch_axes=("data",),
                                       model_axis="model",
                                       comm_runtime="overlapped",
                                       comm_chunks=chunks)
                    rules = ShardingRules(cfg, mesh, ParallelPlan())
                    p_sh = rules.params_shardings(
                        jax.eval_shape(api.init, key))
                    b_sh = rules.batch_shardings(
                        jax.eval_shape(lambda: batch))
                    with set_mesh(mesh):
                        l, g = jax.jit(jax.value_and_grad(
                            lambda p, b: api.loss_fn(p, b, pctx)[0]),
                            in_shardings=(p_sh, b_sh))(params, batch)
                    err_l = abs(float(ref_l) - float(l))
                    err_g = max(jax.tree.leaves(jax.tree.map(
                        lambda a, b: float(jnp.abs(a - b).max()),
                        ref_g, g)))
                    assert err_l < 5e-5 and err_g < 5e-4, (
                        cfg.n_kv_heads, dp, mp, chunks, err_l, err_g)
                    print("OK", cfg.n_kv_heads, dp, mp, chunks)
    """)
    assert out.count("OK") >= 8


def test_overlapped_hot_path_has_no_monolithic_collectives():
    """Acceptance (PR 2-style HLO assertion): growing the layer count must
    grow only the chunk-sized collective-permutes — the per-layer matmul hot
    path contains NO all-gather / all-reduce (the embed psum, pre-head
    gather, and CE stats are per-step constants, not per-layer), while the
    GSPMD lane adds monolithic all-reduces with every layer."""
    _run_subprocess("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import jax, jax.numpy as jnp
        from repro.parallel.jaxcompat import make_mesh, set_mesh
        from repro.configs import get_config
        from repro.models import build_model
        from repro.models.transformer import ParallelCtx
        from repro.parallel.plan import ParallelPlan
        from repro.parallel.sharding import ShardingRules
        from repro.core.roofline import parse_collectives

        base = get_config("llama3_2_1b").reduced()
        mesh = make_mesh((2, 4), ("data", "model"))

        def collect(n_layers, rt):
            cfg = dataclasses.replace(base, n_layers=n_layers)
            api = build_model(cfg, remat=False)
            key = jax.random.PRNGKey(0)
            params = api.init(key)
            batch = {"tokens": jax.random.randint(key, (8, 32), 0,
                              cfg.vocab_size, dtype=jnp.int32),
                     "labels": jax.random.randint(key, (8, 32), 0,
                              cfg.vocab_size, dtype=jnp.int32)}
            pctx = ParallelCtx(mesh=mesh, batch_axes=("data",),
                               model_axis="model", comm_runtime=rt,
                               comm_chunks=1)
            rules = ShardingRules(cfg, mesh, ParallelPlan())
            p_sh = rules.params_shardings(jax.eval_shape(api.init, key))
            b_sh = rules.batch_shardings(jax.eval_shape(lambda: batch))
            # unroll the layer scan so per-layer collectives are visible to
            # the parser (while bodies count once otherwise)
            from repro.models import layers as L
            L.set_analysis_unroll(True)
            try:
                with set_mesh(mesh):
                    comp = jax.jit(
                        lambda p, b: api.loss_fn(p, b, pctx)[0],
                        in_shardings=(p_sh, b_sh)).lower(
                            params, batch).compile()
            finally:
                L.set_analysis_unroll(False)
            return parse_collectives(comp.as_text(), default_group=4)

        o2, o4 = collect(2, "overlapped"), collect(4, "overlapped")
        g2, g4 = collect(2, "gspmd"), collect(4, "gspmd")
        dcp = o4.ops.get("collective-permute", 0) - \
            o2.ops.get("collective-permute", 0)
        dag = o4.ops.get("all-gather", 0) - o2.ops.get("all-gather", 0)
        dar = o4.ops.get("all-reduce", 0) - o2.ops.get("all-reduce", 0)
        assert dcp > 0, (o2.ops, o4.ops)
        assert dag == 0 and dar == 0, (o2.ops, o4.ops)
        # the GSPMD lane pays monolithic all-reduces per layer
        g_dar = g4.ops.get("all-reduce", 0) - g2.ops.get("all-reduce", 0)
        assert g_dar > 0, (g2.ops, g4.ops)
        print("OK", o2.ops, o4.ops, g_dar)
    """)


def test_overlapped_biglstm_matches_gspmd():
    """The overlapped tensor-MP LSTM (gate-major collective-matmul input
    projection) == the plain forward, loss and grads, across meshes/chunks."""
    out = _run_subprocess("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from repro.parallel.jaxcompat import make_mesh, set_mesh
        from repro.configs import get_config
        from repro.models import build_model
        from repro.models.transformer import ParallelCtx
        from repro.parallel.plan import ParallelPlan
        from repro.parallel.sharding import ShardingRules

        cfg = get_config("biglstm").reduced()
        api = build_model(cfg)
        key = jax.random.PRNGKey(0)
        params = api.init(key)
        batch = {"tokens": jax.random.randint(key, (8, 16), 0,
                          cfg.vocab_size, dtype=jnp.int32),
                 "labels": jax.random.randint(key, (8, 16), 0,
                          cfg.vocab_size, dtype=jnp.int32)}
        ref_l, ref_g = jax.value_and_grad(
            lambda p: api.loss_fn(p, batch)[0])(params)
        for dp, mp in ((2, 4), (1, 2)):
            for chunks in (1, 2):
                mesh = make_mesh((dp, mp), ("data", "model"))
                pctx = ParallelCtx(mesh=mesh, batch_axes=("data",),
                                   model_axis="model",
                                   comm_runtime="overlapped",
                                   comm_chunks=chunks)
                rules = ShardingRules(cfg, mesh, ParallelPlan())
                p_sh = rules.params_shardings(jax.eval_shape(api.init, key))
                b_sh = rules.batch_shardings(jax.eval_shape(lambda: batch))
                with set_mesh(mesh):
                    l, g = jax.jit(jax.value_and_grad(
                        lambda p, b: api.loss_fn(p, b, pctx)[0]),
                        in_shardings=(p_sh, b_sh))(params, batch)
                err_l = abs(float(ref_l) - float(l))
                err_g = max(jax.tree.leaves(jax.tree.map(
                    lambda a, b: float(jnp.abs(a - b).max()), ref_g, g)))
                assert err_l < 5e-5 and err_g < 1e-3, (dp, mp, chunks,
                                                      err_l, err_g)
                print("OK", dp, mp, chunks)
    """)
    assert out.count("OK") == 4


def test_bucketed_dp_train_step_bit_equal_and_split():
    """Acceptance (DP half): the bucketed reduce-scatter grad sync produces
    BIT-EQUAL updated params to GSPMD's fused all-reduce, and the compiled
    step contains the per-bucket reduce-scatter/all-gather split with no
    gradient-sized all-reduce."""
    _run_subprocess("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.parallel.jaxcompat import make_mesh, set_mesh
        from repro.configs import get_config
        from repro.models import build_model
        from repro.parallel.plan import ParallelPlan
        from repro.train.steps import init_train_state, make_train_step
        from repro.optim import adamw, warmup_cosine
        from repro.core.roofline import parse_collectives

        cfg = get_config("llama3_2_1b").reduced()
        api = build_model(cfg, remat=False)
        opt = adamw(warmup_cosine(1e-3, 2, 10))
        key = jax.random.PRNGKey(0)
        state = init_train_state(api, opt, key)
        batch = {"tokens": jax.random.randint(key, (8, 16), 0,
                          cfg.vocab_size, dtype=jnp.int32),
                 "labels": jax.random.randint(key, (8, 16), 0,
                          cfg.vocab_size, dtype=jnp.int32)}
        mesh = make_mesh((4, 1), ("data", "model"))
        b_sh = {k: NamedSharding(mesh, P("data", None)) for k in batch}
        s_sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), state)
        outs, comps = {}, {}
        for rt in ("gspmd", "overlapped"):
            plan = ParallelPlan(model_axis=None, comm_runtime=rt)
            step = make_train_step(api, opt, mesh=mesh, plan=plan,
                                   bucket_bytes=256 * 1024)
            with set_mesh(mesh):
                j = jax.jit(step, in_shardings=(s_sh, b_sh))
                comps[rt] = j.lower(state, batch).compile()
                outs[rt] = j(state, batch)
        diff = max(jax.tree.leaves(jax.tree.map(
            lambda a, b: float(jnp.abs(a - b).max()),
            outs["gspmd"][0].params, outs["overlapped"][0].params)))
        assert diff == 0.0, diff
        ov = parse_collectives(comps["overlapped"].as_text(),
                               default_group=4)
        assert ov.ops.get("reduce-scatter", 0) >= 2, ov.ops   # > 1 bucket
        assert ov.ops.get("all-gather", 0) >= 2, ov.ops
        # no gradient-sized all-reduce: any surviving AR is a scalar metric
        from repro.core.roofline import _tensor_bytes
        big_ar = [ln for ln in ov.lines if "all-reduce" in ln
                  and _tensor_bytes(ln) > 1024]
        assert not big_ar, big_ar
        gs = parse_collectives(comps["gspmd"].as_text(), default_group=4)
        assert gs.ops.get("all-reduce", 0) > ov.ops.get("all-reduce", 0)
        print("OK", diff, ov.ops)
    """)


def test_overlapped_train_step_tensor_mp():
    """End-to-end make_train_step on a dp x mp mesh with the overlapped comm
    runtime: one optimizer step must match the GSPMD comm runtime's at fp32
    round-off (same plan, same mesh, only the collective runtime differs)."""
    _run_subprocess("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        from repro.parallel.jaxcompat import make_mesh, set_mesh
        from repro.configs import get_config
        from repro.models import build_model
        from repro.parallel.plan import ParallelPlan
        from repro.train.steps import (_make_pctx, init_train_state,
                                       make_train_step, shardings_for)
        from repro.optim import adamw, warmup_cosine

        cfg = get_config("llama3_2_1b").reduced()
        api = build_model(cfg, remat=False)
        opt = adamw(warmup_cosine(1e-3, 2, 10))
        key = jax.random.PRNGKey(0)
        state = init_train_state(api, opt, key)
        batch = {"tokens": jax.random.randint(key, (8, 32), 0,
                          cfg.vocab_size, dtype=jnp.int32),
                 "labels": jax.random.randint(key, (8, 32), 0,
                          cfg.vocab_size, dtype=jnp.int32)}
        mesh = make_mesh((2, 2), ("data", "model"))
        i32 = jnp.int32
        specs = {"tokens": jax.ShapeDtypeStruct((8, 32), i32),
                 "labels": jax.ShapeDtypeStruct((8, 32), i32)}
        outs = {}
        for rt in ("gspmd", "overlapped"):
            plan = ParallelPlan(comm_runtime=rt, comm_chunks=2)
            pctx = _make_pctx(mesh, plan, batch_shardable=True)
            s_sh, b_sh = shardings_for(api, mesh, plan, opt, specs)
            step = make_train_step(api, opt, mesh=mesh, plan=plan, pctx=pctx)
            with set_mesh(mesh):
                outs[rt] = jax.jit(step, in_shardings=(s_sh, b_sh))(
                    state, batch)
        diff = max(jax.tree.leaves(jax.tree.map(
            lambda a, b: float(jnp.abs(a - b).max()),
            outs["gspmd"][0].params, outs["overlapped"][0].params)))
        l0 = float(outs["gspmd"][1]["loss"])
        l1 = float(outs["overlapped"][1]["loss"])
        assert abs(l0 - l1) < 5e-5, (l0, l1)
        assert diff < 5e-4, diff
        print("OK", diff)
    """)


@pytest.mark.slow
def test_collective_overlap_sweep_smoke():
    """The benchmark's smoke lane runs end to end and its internal HLO/wire
    assertions (ring-model wire bytes, no monolithic collectives) hold."""
    out = _run_subprocess("""
        import sys
        sys.argv = ["bench", "--smoke", "--out",
                    "/tmp/BENCH_collectives_test.json"]
        from benchmarks.collective_overlap_sweep import main
        rc = main(["--smoke", "--out", "/tmp/BENCH_collectives_test.json"])
        assert rc == 0
        import json
        rec = json.load(open("/tmp/BENCH_collectives_test.json"))
        assert rec["tensor_mp"]["points"], rec
        assert "planner_crossover" in rec
        print("OK")
    """)
    assert "OK" in out
