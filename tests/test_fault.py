"""Fault-injection lane: hardened checkpoints, supervised loop, elastic resume.

The load-bearing pins:
- checkpoint integrity is *typed*: structural mismatch vs the restore target
  raises ``ValueError`` (the old bare ``assert`` vanished under ``python
  -O``), on-disk damage raises ``CheckpointCorruptionError``, and
  ``restore_latest_valid`` falls back over corrupt files newest-first;
- a seeded fault schedule (step failures, checkpoint corruption, preemption
  kills, stalls) recovers automatically and finishes with params/optimizer
  state BIT-EQUAL to an uninterrupted run on the same topology — resume
  replays no sample and drops none (exact data-order resume);
- the kill@N + ``--resume`` CLI cycle is bit-equal across the schedule
  (gpipe/1f1b) and comm-runtime (gspmd/overlapped) variants;
- elastic DP grow/shrink: a 16-way-DP checkpoint restores BIT-EQUAL onto 8-
  and 32-device meshes (params and optimizer state) and training continues.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import msgpack
import numpy as np
import pytest

from repro.checkpoint import (CheckpointCorruptionError, checkpoint_step,
                              latest_checkpoint, list_checkpoints,
                              restore_checkpoint, restore_latest_valid,
                              save_checkpoint, verify_checkpoint,
                              wait_for_saves)
from repro.configs import get_config
from repro.data import DataPipeline, make_lm_dataset
from repro.models import build_model
from repro.optim import adamw, constant_lr
from repro.train.fault import (Fault, FaultInjector, InjectedFault,
                               KILL_EXIT_CODE, corrupt_checkpoint,
                               parse_fault_schedule, run_supervised)
from repro.train.loop import LoopConfig, train_loop
from repro.train.steps import (TrainState, eval_train_state, init_train_state,
                               make_train_step)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# -- helpers -----------------------------------------------------------------

def _tiny_state():
    return TrainState(params={"w": jnp.arange(6.0).reshape(2, 3),
                              "b": jnp.ones((3,), jnp.int32)},
                      opt_state={"m": {"w": jnp.zeros((2, 3))}},
                      step=jnp.asarray(4, jnp.int32))


def _like(state):
    return jax.tree.map(np.zeros_like, jax.device_get(state))


def _leaves_bytes(fname):
    payload = msgpack.unpackb(open(fname, "rb").read(), raw=False)
    return payload["leaves"], payload["step"]


def _leaves_arrays(fname):
    payload = msgpack.unpackb(open(fname, "rb").read(), raw=False)
    return [np.frombuffer(buf, np.dtype(m["dtype"])).reshape(m["shape"])
            for m, buf in zip(payload["manifest"], payload["leaves"])
            ], payload["step"]


def _run_cli(args, expect_rc=0, timeout=900):
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-m", "repro.launch.train"] + args,
                       capture_output=True, text=True, env=env,
                       timeout=timeout)
    assert r.returncode == expect_rc, (
        f"rc={r.returncode} (expected {expect_rc})\nstdout:\n{r.stdout}"
        f"\nstderr:\n{r.stderr[-3000:]}")
    return r.stdout


def _run_py(code, timeout=900):
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=timeout)
    assert r.returncode == 0, (f"stdout:\n{r.stdout}\n"
                               f"stderr:\n{r.stderr[-3000:]}")
    return r.stdout


# -- hardened checkpoint format ----------------------------------------------

def test_restore_leaf_count_mismatch_raises_value_error(tmp_path):
    """Regression: the old bare ``assert`` on leaf count silently vanished
    under ``python -O``; the check is now a shaped ValueError."""
    state = _tiny_state()
    f = save_checkpoint(str(tmp_path), state, 4)
    like = _like(state)
    with pytest.raises(ValueError, match="4 leaves.*3 — .*different"):
        restore_checkpoint(f, {"params": like.params, "step": like.step})


def test_restore_validates_per_leaf_dtype_and_shape(tmp_path):
    state = _tiny_state()
    f = save_checkpoint(str(tmp_path), state, 4)
    like = _like(state)
    wrong_dtype = dataclasses.replace(
        like, params=dict(like.params, b=np.zeros((3,), np.float32)))
    with pytest.raises(ValueError, match=r"params/b.*int32\[3\].*expects "
                                         r"float32"):
        restore_checkpoint(f, wrong_dtype)
    wrong_shape = dataclasses.replace(
        like, params=dict(like.params, w=np.zeros((3, 2), np.float32)))
    with pytest.raises(ValueError, match=r"params/w.*\[2, 3\].*\[3, 2\]"):
        restore_checkpoint(f, wrong_shape)


def test_crc_detects_bitflip_and_fallback_restores_previous(tmp_path):
    state = _tiny_state()
    f1 = save_checkpoint(str(tmp_path), state, 1)
    state2 = dataclasses.replace(state, step=jnp.asarray(2, jnp.int32))
    f2 = save_checkpoint(str(tmp_path), state2, 2)
    corrupt_checkpoint(f2, "bitflip")
    like = _like(state)
    with pytest.raises((CheckpointCorruptionError, ValueError)):
        restore_checkpoint(f2, like)
    with pytest.warns(UserWarning, match="skipping ckpt_00000002"):
        restored, fname = restore_latest_valid(str(tmp_path), like)
    assert fname == f1
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_truncation_detected(tmp_path):
    state = _tiny_state()
    f = save_checkpoint(str(tmp_path), state, 1)
    corrupt_checkpoint(f, "truncate")
    with pytest.raises(CheckpointCorruptionError):
        verify_checkpoint(f)


def test_all_corrupt_directory_raises_not_fresh_init(tmp_path):
    """Every checkpoint corrupt -> typed error, NOT (None, None): silently
    returning nothing would make the supervisor fresh-init at step 0 and
    loop, masking total state loss as a routine restart."""
    state = _tiny_state()
    for step in (1, 2):
        corrupt_checkpoint(save_checkpoint(str(tmp_path), state, step),
                           "truncate")
    with pytest.warns(UserWarning, match="skipping"):
        with pytest.raises(CheckpointCorruptionError,
                           match=r"all 2 checkpoint\(s\).*failed"):
            restore_latest_valid(str(tmp_path), _like(state))
    # an empty directory is still a clean fresh start, not an error
    empty = tmp_path / "empty"
    empty.mkdir()
    assert restore_latest_valid(str(empty), _like(state)) == (None, None)


def test_verify_checkpoint_reports_manifest(tmp_path):
    state = _tiny_state()
    f = save_checkpoint(str(tmp_path), state, 7)
    info = verify_checkpoint(f)
    assert info["step"] == 7 and info["version"] == 2
    assert info["n_leaves"] == len(jax.tree.leaves(state))
    assert checkpoint_step(f) == 7


def test_keep_last_retention_and_orphan_tmp_cleanup(tmp_path):
    state = _tiny_state()
    orphan = tmp_path / "ckpt_00000001.msgpack.tmp-9999"
    orphan.write_bytes(b"half-written garbage from a dead process")
    for s in range(1, 6):
        save_checkpoint(str(tmp_path), state, s, keep_last=2)
    names = [os.path.basename(p) for p in list_checkpoints(str(tmp_path))]
    assert names == ["ckpt_00000004.msgpack", "ckpt_00000005.msgpack"]
    assert not orphan.exists()
    assert not [n for n in os.listdir(tmp_path) if ".tmp" in n]


def test_background_save_is_bit_equal_to_sync(tmp_path):
    state = _tiny_state()
    f_sync = save_checkpoint(str(tmp_path / "a"), state, 3)
    f_bg = save_checkpoint(str(tmp_path / "b"), state, 3, background=True)
    wait_for_saves()
    la, _ = _leaves_bytes(f_sync)
    lb, _ = _leaves_bytes(f_bg)
    assert la == lb
    verify_checkpoint(f_bg)


def test_legacy_v1_checkpoint_still_restores(tmp_path):
    state = _tiny_state()
    flat, treedef = jax.tree.flatten(state)
    v1 = {"treedef": str(treedef),
          "leaves": [{"dtype": str(np.asarray(x).dtype),
                      "shape": list(np.asarray(x).shape),
                      "data": np.asarray(x).tobytes()} for x in flat]}
    f = str(tmp_path / "ckpt_00000004.msgpack")
    with open(f, "wb") as fh:
        fh.write(msgpack.packb(v1, use_bin_type=True))
    restored = restore_checkpoint(f, _like(state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -- fault schedule / injector -----------------------------------------------

def test_parse_fault_schedule():
    faults = parse_fault_schedule(
        "fail@5x2, kill@7, corrupt@10:truncate, stall@3:0.4, corrupt@12")
    assert [(f.kind, f.step) for f in faults] == [
        ("fail", 5), ("kill", 7), ("corrupt", 10), ("stall", 3),
        ("corrupt", 12)]
    assert faults[0].times == 2
    assert faults[2].mode == "truncate" and faults[4].mode == "bitflip"
    assert faults[3].seconds == pytest.approx(0.4)
    with pytest.raises(ValueError, match="kind@step"):
        parse_fault_schedule("fail5")
    with pytest.raises(ValueError, match="unknown fault kind"):
        parse_fault_schedule("explode@3")
    with pytest.raises(ValueError, match="unknown corrupt mode"):
        Fault("corrupt", 3, mode="scribble")


def test_parse_replica_fault_schedule():
    """The serving extension of the grammar: replica-keyed forms for the
    multi-replica router.  ``stall`` disambiguates by arg count — one arg
    is the training form (seconds), two is the replica form
    (replica, seconds)."""
    faults = parse_fault_schedule(
        "kill@5:1, stall@7:0:0.5, nanlogits@9:1, stall@3:0.4, kill@8")
    assert [(f.kind, f.step, f.replica) for f in faults] == [
        ("kill", 5, 1), ("stall", 7, 0), ("nanlogits", 9, 1),
        ("stall", 3, None), ("kill", 8, None)]
    assert faults[1].seconds == pytest.approx(0.5)
    assert faults[3].seconds == pytest.approx(0.4)   # training form intact
    with pytest.raises(ValueError, match="nanlogits.*replica"):
        parse_fault_schedule("nanlogits@9")          # requires a replica
    with pytest.raises(ValueError, match="replica"):
        Fault("nanlogits", 9)
    with pytest.raises(ValueError, match="replica must be >= 0"):
        Fault("kill", 5, replica=-1)
    with pytest.raises(ValueError):
        parse_fault_schedule("fail@5:1")             # fail takes no args


def _recording_pipeline(n_per_epoch=5, known_spe=True):
    def epoch_fn(e):
        return iter([{"eid": np.asarray(e), "bid": np.asarray(i)}
                     for i in range(n_per_epoch)])
    return DataPipeline(epoch_fn,
                        steps_per_epoch=n_per_epoch if known_spe else None)


def _recording_step(log):
    def step(state, batch):
        log.append((int(batch["eid"]), int(batch["bid"])))
        return (TrainState(state.params, state.opt_state, state.step + 1),
                {"loss": jnp.asarray(1.0)})
    return step


def _zero_state(step=0):
    return TrainState(params={"w": jnp.zeros(())}, opt_state=(),
                      step=jnp.asarray(step, jnp.int32))


@pytest.mark.parametrize("known_spe", [True, False])
def test_exact_data_order_resume(known_spe):
    """Resume at step s consumes exactly the batches an uninterrupted run
    sees from step s on — across an epoch boundary, no replay, no drop."""
    straight, resumed = [], []
    cfg = LoopConfig(total_steps=12, log_every=100)
    train_loop(_recording_step(straight), _zero_state(0),
               _recording_pipeline(known_spe=known_spe), cfg,
               log_fn=lambda m: None)
    train_loop(_recording_step(resumed), _zero_state(7),
               _recording_pipeline(known_spe=known_spe), cfg,
               log_fn=lambda m: None)
    assert len(straight) == 12
    assert straight[7:] == resumed
    assert straight == [(e, i) for e in range(3) for i in range(5)][:12]


def test_empty_epoch_raises_instead_of_spinning():
    pipe = DataPipeline(lambda e: iter([]))
    with pytest.raises(RuntimeError, match="empty epoch"):
        train_loop(_recording_step([]), _zero_state(0), pipe,
                   LoopConfig(total_steps=3), log_fn=lambda m: None)


def test_injected_failure_retried_in_place():
    """fail@3 with max_retries=1: the loop retries the same batch from the
    held state and completes with no step lost or duplicated."""
    log = []
    inj = FaultInjector(parse_fault_schedule("fail@3"),
                        log_fn=lambda m: None)
    cfg = LoopConfig(total_steps=6, max_retries=1, retry_backoff_s=0.0)
    summary = train_loop(inj.wrap_step(_recording_step(log)), _zero_state(0),
                         _recording_pipeline(), cfg, log_fn=lambda m: None)
    assert summary["retries"] == 1
    assert inj.fired == [("fail", 3)]
    assert summary["steps"] == 6 and len(log) == 6
    assert log == [(0, i) for i in range(5)] + [(1, 0)]


def test_retry_exhaustion_kills_attempt_and_propagates():
    inj = FaultInjector([Fault("fail", 2, times=5)], log_fn=lambda m: None)
    with pytest.raises(InjectedFault):
        train_loop(inj.wrap_step(_recording_step([])), _zero_state(0),
                   _recording_pipeline(),
                   LoopConfig(total_steps=4, max_retries=1,
                              retry_backoff_s=0.0),
                   log_fn=lambda m: None)


def test_watchdog_flags_injected_stall():
    inj = FaultInjector(parse_fault_schedule("stall@2:0.25"),
                        log_fn=lambda m: None)
    cfg = LoopConfig(total_steps=4, watchdog_timeout_s=0.05)
    summary = train_loop(inj.wrap_step(_recording_step([])), _zero_state(0),
                         _recording_pipeline(), cfg, log_fn=lambda m: None)
    assert summary["hangs"] >= 1
    assert summary["steps"] == 4          # the stalled step still completed
    assert inj.fired == [("stall", 2)]


def test_final_checkpoint_guaranteed_at_loop_exit(tmp_path):
    """ckpt_every=0 still leaves a resumable final checkpoint."""
    cfg = LoopConfig(total_steps=5, ckpt_every=0, ckpt_dir=str(tmp_path))
    summary = train_loop(_recording_step([]), _zero_state(0),
                         _recording_pipeline(), cfg, log_fn=lambda m: None)
    f = latest_checkpoint(str(tmp_path))
    assert f is not None and checkpoint_step(f) == 5
    assert summary["last_checkpoint_step"] == 5


# -- supervised end-to-end recovery (real model, in-process) -----------------

def _lm_setup(steps=8, batch=4, seq=8):
    cfg = dataclasses.replace(get_config("llama3_2_1b").reduced(),
                              vocab_size=32)
    api = build_model(cfg)
    opt = adamw(constant_lr(3e-3))
    data = make_lm_dataset(vocab=32, seq_len=seq, n_items=64)

    def epoch_fn(e):
        return iter(list(data.epoch(e, batch)))

    pipe = DataPipeline(epoch_fn, steps_per_epoch=data.steps_per_epoch(batch))
    step_fn = jax.jit(make_train_step(api, opt), donate_argnums=(0,))
    init_fn = lambda: init_train_state(api, opt, jax.random.PRNGKey(0))
    return api, opt, pipe, step_fn, init_fn


def test_supervisor_recovers_bit_equal_to_uninterrupted(tmp_path):
    """The acceptance pin, in-process: a schedule that (a) fails step 5 past
    the retry budget and (b) corrupts the newest checkpoint recovers by
    falling back to the last valid checkpoint and finishes with params AND
    optimizer state bit-equal to a straight run."""
    api, opt, pipe, step_fn, init_fn = _lm_setup()
    cfg = LoopConfig(total_steps=8, ckpt_every=2, ckpt_dir=str(tmp_path),
                     max_retries=1, retry_backoff_s=0.0, log_every=100)

    straight = train_loop(step_fn, init_fn(), pipe,
                          dataclasses.replace(cfg, ckpt_dir=""),
                          log_fn=lambda m: None)

    # fail@5 x3 exhausts max_retries=1 -> attempt dies after the step-4
    # checkpoint; corrupt@4 damages that checkpoint, forcing the fallback
    # to the step-2 one.  The supervisor restores and re-runs 3..8.
    inj = FaultInjector(parse_fault_schedule("fail@5x3, corrupt@4:bitflip"),
                        log_fn=lambda m: None)
    with pytest.warns(UserWarning, match="skipping ckpt_00000004"):
        summary = run_supervised(inj.wrap_step(step_fn), pipe, cfg,
                                 init_fn=init_fn,
                                 like=eval_train_state(api, opt),
                                 max_restarts=2, restart_backoff_s=0.0,
                                 log_fn=lambda m: None,
                                 on_checkpoint=inj.after_save)
    assert summary["restarts"] == 1
    assert summary["steps"] == 8
    assert ("corrupt", 4) in inj.fired and ("fail", 5) in inj.fired
    a = jax.device_get(straight["state"])
    b = jax.device_get(summary["state"])
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_supervisor_backoff_sequence_pinned():
    """The restart backoff doubles per attempt; ``sleep_fn`` injection pins
    the exact wait sequence without burning wall-clock time."""
    waits = []
    inj = FaultInjector([Fault("fail", 2, times=100)], log_fn=lambda m: None)
    cfg = LoopConfig(total_steps=4, max_retries=0, retry_backoff_s=0.0)
    with pytest.raises(InjectedFault):
        run_supervised(inj.wrap_step(_recording_step([])),
                       _recording_pipeline(), cfg, init_fn=_zero_state,
                       max_restarts=3, restart_backoff_s=0.05,
                       log_fn=lambda m: None, sleep_fn=waits.append)
    assert waits == pytest.approx([0.05, 0.10, 0.20])


# -- CLI kill + resume (subprocess) ------------------------------------------

def _cli_base(ckpt_dir, extra=(), steps=12):
    return ["--arch", "llama3_2_1b", "--reduced", "--steps", str(steps),
            "--batch", "8", "--seq", "16", "--ckpt-dir", ckpt_dir,
            "--ckpt-every", "5"] + list(extra)


def _final_ckpt_leaves(ckpt_dir, expect_step):
    f = latest_checkpoint(ckpt_dir)
    assert f is not None, ckpt_dir
    leaves, step = _leaves_bytes(f)
    assert step == expect_step, (step, expect_step)
    return leaves


def test_cli_kill_and_resume_bit_equal():
    """Preemption via the real CLI: kill@9 (after the step-5 checkpoint),
    then --resume; the final checkpoint is bit-identical to a straight
    run's, params and optimizer state included."""
    import tempfile
    with tempfile.TemporaryDirectory() as td:
        d_kill = os.path.join(td, "kill")
        d_straight = os.path.join(td, "straight")
        _run_cli(_cli_base(d_kill, ["--fault", "kill@9"]),
                 expect_rc=KILL_EXIT_CODE)
        assert checkpoint_step(latest_checkpoint(d_kill)) == 5
        out = _run_cli(_cli_base(d_kill, ["--resume"]))
        assert "restored ckpt_00000005" in out
        assert "resuming at step 5" in out
        _run_cli(_cli_base(d_straight))
        assert (_final_ckpt_leaves(d_kill, 12)
                == _final_ckpt_leaves(d_straight, 12))


@pytest.mark.slow
@pytest.mark.parametrize("variant", [
    ("pipe-gpipe", ["--parallel", "pipe=2,micro=2,sched=gpipe"]),
    ("pipe-1f1b", ["--parallel", "pipe=2,micro=2,sched=1f1b"]),
    ("dp-gspmd", ["--parallel", "dp=2,mp=1"]),
    ("dp-overlapped", ["--parallel", "dp=2,mp=1",
                       "--comm-runtime", "overlapped"]),
], ids=lambda v: v[0])
def test_cli_kill_resume_bit_equal_across_runtimes(variant):
    """Kill-and-resume bit-equality must hold whichever runtime carries the
    step: pipeline schedules (gpipe/1f1b) and comm runtimes
    (gspmd/overlapped bucketed DP sync)."""
    import tempfile
    _, extra = variant
    with tempfile.TemporaryDirectory() as td:
        d_kill = os.path.join(td, "kill")
        d_straight = os.path.join(td, "straight")
        _run_cli(_cli_base(d_kill, extra + ["--fault", "kill@9"], steps=10),
                 expect_rc=KILL_EXIT_CODE)
        _run_cli(_cli_base(d_kill, extra + ["--resume"], steps=10))
        _run_cli(_cli_base(d_straight, extra, steps=10))
        assert (_final_ckpt_leaves(d_kill, 10)
                == _final_ckpt_leaves(d_straight, 10))


# -- elastic DP grow/shrink resume -------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("dp_new", [8, 32])
def test_elastic_dp_grow_shrink_resume(dp_new, tmp_path):
    """A 16-way-DP run killed mid-flight resumes on 8 or 32 devices: the
    re-sharded restore is BIT-EQUAL to the checkpoint (params and optimizer
    state, pinned inside the resized-mesh subprocess), and training
    continues to completion with a final checkpoint whose params match the
    uninterrupted 16-way run at fp32 round-off (cross-topology gradient
    reductions reassociate, so exact bitness across DP degrees is not a
    meaningful target — same-topology bitness is pinned above)."""
    d16 = str(tmp_path / "dp16")
    d16_straight = str(tmp_path / "dp16_straight")
    args16 = ["--arch", "llama3_2_1b", "--reduced", "--steps", "6",
              "--batch", "32", "--seq", "8", "--parallel", "dp=16,mp=1",
              "--max-local-devices", "16", "--ckpt-every", "3"]
    _run_cli(args16 + ["--ckpt-dir", d16, "--fault", "kill@5"],
             expect_rc=KILL_EXIT_CODE)
    ck = latest_checkpoint(d16)
    assert checkpoint_step(ck) == 3

    # inside the resized mesh: restore with re-shard, then pin bit-equality
    # of every leaf against the raw checkpoint buffers
    out = _run_py(f"""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count={dp_new}")
        import jax, msgpack, numpy as np
        from repro.checkpoint import restore_checkpoint
        from repro.configs import get_config
        from repro.launch.mesh import make_mesh
        from repro.models import build_model
        from repro.optim import adamw, warmup_cosine
        from repro.parallel.plan import ParallelPlan
        from repro.train.steps import eval_train_state, shardings_for
        cfg = get_config("llama3_2_1b").reduced()
        api = build_model(cfg)
        opt = adamw(warmup_cosine(3e-3, 20, 6))
        mesh = make_mesh(dp={dp_new}, mp=1)
        plan = ParallelPlan(dp_axes=("data",), model_axis=None)
        i32 = jax.numpy.int32
        specs = {{"tokens": jax.ShapeDtypeStruct((32, 8), i32),
                  "labels": jax.ShapeDtypeStruct((32, 8), i32)}}
        state_sh, _ = shardings_for(api, mesh, plan, opt, specs)
        state = restore_checkpoint({ck!r}, eval_train_state(api, opt),
                                   state_sh)
        host = [np.asarray(x) for x in jax.tree.leaves(jax.device_get(state))]
        raw = msgpack.unpackb(open({ck!r}, "rb").read(), raw=False)
        assert len(host) == len(raw["leaves"])
        for i, (h, b) in enumerate(zip(host, raw["leaves"])):
            assert h.tobytes() == b, f"leaf {{i}} not bit-equal after reshard"
        print("RESHARD_BITEQUAL", len(host))
    """)
    assert "RESHARD_BITEQUAL" in out

    # continue training on the new DP degree through the CLI resume path
    out = _run_cli(["--arch", "llama3_2_1b", "--reduced", "--steps", "6",
                    "--batch", "32", "--seq", "8",
                    "--parallel", f"dp={dp_new},mp=1",
                    "--max-local-devices", str(dp_new),
                    "--ckpt-every", "3", "--ckpt-dir", d16, "--resume"])
    assert f"onto {dp_new}-way DP" in out
    assert "resuming at step 3" in out

    # uninterrupted 16-way reference: same steps, no faults
    _run_cli(args16 + ["--ckpt-dir", d16_straight])
    fin, step = _leaves_arrays(latest_checkpoint(d16))
    ref, step_ref = _leaves_arrays(latest_checkpoint(d16_straight))
    assert step == 6 and step_ref == 6
    assert len(fin) == len(ref)
    for i, (a, b) in enumerate(zip(fin, ref)):
        np.testing.assert_allclose(
            np.asarray(a, np.float64), np.asarray(b, np.float64),
            rtol=2e-4, atol=1e-5,
            err_msg=f"leaf {i} diverged beyond round-off across the "
                    f"16->{dp_new} topology change")
