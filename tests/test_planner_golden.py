"""Golden regression pins for the 3-way planner on the paper's three archs.

These values ARE expected to move when the cost model changes — that is the
point: any edit to the tensor/pipeline SU^M models, the SE_N comm model, the
epoch-inflation prior, or the memory filter surfaces here as a visible,
reviewable diff instead of silently reshaping every downstream projection.
Update the table deliberately, alongside the cost-model change.

Settings pinned to the planner defaults used by ``--parallel auto``:
``default_epoch_model``, mini_batch=16, seq_len=4096, TPU-v5e HardwareModel,
se_perfect=False.
"""
import pytest

from repro.configs import get_config
from repro.core.planner import HybridPlanner, default_epoch_model

# (arch, devices) -> (mp_kind, pods, dp, mp, microbatches, speedup)
GOLDEN = {
    ("inception_v3", 64): ("none", 1, 64, 1, 1, 1.4207),
    ("inception_v3", 256): ("tensor", 1, 8, 32, 1, 0.774818),
    ("inception_v3", 1024): ("tensor", 4, 8, 32, 1, 0.435361),
    ("gnmt", 64): ("pipeline", 1, 16, 4, 8, 15.0249),
    ("gnmt", 256): ("pipeline", 1, 64, 4, 8, 5.45537),
    ("gnmt", 1024): ("pipeline", 4, 64, 4, 8, 1.40307),
    ("biglstm", 64): ("pipeline", 1, 32, 2, 8, 34.1723),
    ("biglstm", 256): ("pipeline", 1, 128, 2, 8, 19.685),
    ("biglstm", 1024): ("pipeline", 4, 128, 2, 8, 5.35752),
}


@pytest.mark.parametrize("arch", ["inception_v3", "gnmt", "biglstm"])
def test_planner_golden_choices(arch):
    cfg = get_config(arch)
    planner = HybridPlanner(cfg, epoch_model=default_epoch_model(cfg))
    for devices in (64, 256, 1024):
        kind, pods, dp, mp, micro, speedup = GOLDEN[(arch, devices)]
        best = planner.best(devices)
        got = (best.mp_kind, best.pods, best.dp, best.mp, best.microbatches)
        assert got == (kind, pods, dp, mp, micro), (
            f"{arch}@{devices}: planner now picks {got}, golden is "
            f"{(kind, pods, dp, mp, micro)} — if the cost-model change is "
            f"intentional, update GOLDEN")
        assert best.speedup == pytest.approx(speedup, rel=1e-3), (
            f"{arch}@{devices}: projected SU moved")


def test_paper_rnn_archs_pipeline_at_scale():
    """The paper's §4.4 claim as a pinned planner outcome: at >= 256 devices
    the LSTM-family archs' arg-max plan is pipeline-MP, not tensor or DP."""
    for arch in ("gnmt", "biglstm"):
        cfg = get_config(arch)
        planner = HybridPlanner(cfg, epoch_model=default_epoch_model(cfg))
        for devices in (256, 1024):
            best = planner.best(devices)
            assert best.mp_kind == "pipeline", (arch, devices, best)
            assert best.plan.is_pipeline and best.plan.microbatches > 1
