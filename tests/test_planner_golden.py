"""Golden regression pins for the unified planner on the paper's three archs.

These values ARE expected to move when the cost model changes — that is the
point: any edit to the tensor/pipeline SU^M models, the pipeline-schedule
bubble/memory models, the SE_N comm model, the epoch-inflation prior, or the
memory filter surfaces here as a visible, reviewable diff instead of silently
reshaping every downstream projection.  Update the table deliberately,
alongside the cost-model change.

Settings pinned to the planner defaults used by ``--parallel auto``:
``default_epoch_model``, mini_batch=16, seq_len=4096, TPU-v5e HardwareModel,
se_perfect=False, micro candidates (2, 4, 8, 16), schedules searched
(gpipe / 1f1b / interleaved v=2).

History: the schedule dimension (this PR) moved the RNN archs from
(gpipe, K=8) to (1f1b, K=16) — 1f1b's min(K, S) activation residency makes
K=16 the memory-cheapest point at the identical projected step time, and
the larger K shrinks the bubble (gnmt 4-stage: 3/11 -> 3/19).
"""
import pytest

from repro.configs import get_config
from repro.core.planner import (HybridPlanner, default_epoch_model,
                                per_device_mem_bytes)
from repro.parallel.pipeline import SCHEDULE_KINDS

# (arch, devices) -> (mp_kind, pods, dp, mp, microbatches, schedule, speedup)
# History: ISSUE 5's latency (alpha) term in the tensor-MP all-reduce model
# nudged the inception SU pins down slightly (the RNN archs' pipeline SU and
# their SE_N ring model already carried alpha).
GOLDEN = {
    ("inception_v3", 64): ("none", 1, 64, 1, 1, "-", 1.420695),
    ("inception_v3", 256): ("tensor", 1, 8, 32, 1, "-", 0.765736),
    ("inception_v3", 1024): ("tensor", 4, 8, 32, 1, "-", 0.430258),
    ("gnmt", 64): ("pipeline", 1, 16, 4, 16, "1f1b", 17.395472),
    ("gnmt", 256): ("pipeline", 1, 64, 4, 16, "1f1b", 6.316095),
    ("gnmt", 1024): ("pipeline", 4, 64, 4, 16, "1f1b", 1.624438),
    ("biglstm", 64): ("pipeline", 1, 32, 2, 16, "1f1b", 36.182307),
    ("biglstm", 256): ("pipeline", 1, 128, 2, 16, "1f1b", 20.842839),
    ("biglstm", 1024): ("pipeline", 4, 128, 2, 16, "1f1b", 5.672646),
    # ISSUE 8: the context axis (sequence-sharded KV ring) wins the arg-max
    # for the dense decoder — the ring's 3 ppermute rotations of the small
    # GQA KV block undercut tensor-MP's per-layer all-reduces, and the
    # full-gradient sync over all n*m devices still clears Eq. 6 at 4k seq
    ("llama3_2_1b", 64): ("context", 1, 8, 8, 1, "-", 53.426237),
    ("llama3_2_1b", 256): ("context", 1, 16, 16, 1, "-", 165.982467),
    ("llama3_2_1b", 1024): ("context", 4, 8, 32, 1, "-", 364.165526),
}

# comm-runtime crossover pins (ISSUE 5): for an arch the overlapped runtime
# actually executes (llama: homogeneous dense decoder), hiding
# MEASURED_OVERLAP of the Megatron all-reduce time lifts tensor-MP SU^M.
# Inception's CNN family has NO overlapped tensor-MP path, so requesting the
# runtime must change nothing — the planner only credits speedups the
# executor can deliver (comm_runtime_supported).
# History: ISSUE 8 replaced the 0.6 overlap placeholder with the MEASURED
# ``tensor_mp.overlap_constant_proxy`` from BENCH_collectives.json (~0.24 on
# this host's emulated mesh) — hiding less comm than assumed moved the
# overlapped m=4 crossover back from 8 to gspmd's 16; the SU lift survives
# (asserted below), the tipping point no longer does at this host's constant.
GOLDEN_CROSSOVER = {
    ("llama3_2_1b", "gspmd", 2): 8,
    ("llama3_2_1b", "overlapped", 2): 8,
    ("llama3_2_1b", "gspmd", 4): 16,
    ("llama3_2_1b", "overlapped", 4): 16,
    ("inception_v3", "gspmd", 2): None,
    ("inception_v3", "overlapped", 2): None,
    ("inception_v3", "gspmd", 4): None,
    ("inception_v3", "overlapped", 4): None,
}


@pytest.mark.parametrize("arch", ["inception_v3", "gnmt", "biglstm",
                                  "llama3_2_1b"])
def test_planner_golden_choices(arch):
    cfg = get_config(arch)
    planner = HybridPlanner(cfg, epoch_model=default_epoch_model(cfg))
    for devices in (64, 256, 1024):
        kind, pods, dp, mp, micro, sched, speedup = GOLDEN[(arch, devices)]
        best = planner.best(devices)
        got = (best.mp_kind, best.pods, best.dp, best.mp, best.microbatches,
               best.schedule)
        assert got == (kind, pods, dp, mp, micro, sched), (
            f"{arch}@{devices}: planner now picks {got}, golden is "
            f"{(kind, pods, dp, mp, micro, sched)} — if the cost-model "
            f"change is intentional, update GOLDEN")
        assert best.speedup == pytest.approx(speedup, rel=1e-3), (
            f"{arch}@{devices}: projected SU moved")


def test_comm_runtime_shifts_crossover_golden():
    """ISSUE 5 pin: selecting ``comm_runtime="overlapped"`` must shift the
    DP-vs-hybrid crossover device count for an arch the overlapped runtime
    executes (llama), must change NOTHING for an arch it cannot (inception's
    CNN blocks fall back to GSPMD — the planner never credits a speedup the
    executor cannot deliver), and the emitted plans must be stamped with the
    runtime that was costed."""
    for (arch, rt, m), want in GOLDEN_CROSSOVER.items():
        cfg = get_config(arch)
        planner = HybridPlanner(cfg, epoch_model=default_epoch_model(cfg),
                                comm_runtime=rt)
        got = planner.crossover(m)
        assert got == want, (
            f"{arch} crossover(m={m}) under {rt} now {got}, golden {want} — "
            f"update GOLDEN_CROSSOVER with the cost-model change")
    cfg = get_config("llama3_2_1b")
    over = HybridPlanner(cfg, epoch_model=default_epoch_model(cfg),
                         comm_runtime="overlapped")
    base = HybridPlanner(cfg, epoch_model=default_epoch_model(cfg))
    assert over.best(256).speedup > base.best(256).speedup
    assert over.best(256).plan.comm_runtime == "overlapped"
    assert base.best(256).plan.comm_runtime == "gspmd"
    # ineligible arch: identical choices, plans stamped with the gspmd
    # runtime that will actually carry them
    cnn = get_config("inception_v3")
    cnn_over = HybridPlanner(cnn, epoch_model=default_epoch_model(cnn),
                             comm_runtime="overlapped")
    cnn_base = HybridPlanner(cnn, epoch_model=default_epoch_model(cnn))
    assert cnn_over.best(256).speedup == cnn_base.best(256).speedup
    assert cnn_over.best(256).plan.comm_runtime == "gspmd"


def test_paper_rnn_archs_pipeline_at_scale():
    """The paper's §4.4 claim as a pinned planner outcome: at >= 256 devices
    the LSTM-family archs' arg-max plan is pipeline-MP, not tensor or DP."""
    for arch in ("gnmt", "biglstm"):
        cfg = get_config(arch)
        planner = HybridPlanner(cfg, epoch_model=default_epoch_model(cfg))
        for devices in (256, 1024):
            best = planner.best(devices)
            assert best.mp_kind == "pipeline", (arch, devices, best)
            assert best.plan.is_pipeline and best.plan.microbatches > 1


def test_planner_selects_non_gpipe_schedule():
    """With the schedule dimension searched, the arg-max for the paper's RNN
    archs is a non-GPipe schedule: 1f1b matches gpipe's projected step time
    at every (M, K) but holds min(K, S) instead of K micro-batch activations,
    so the tie breaks toward it and larger K become the cheapest points."""
    for arch in ("gnmt", "biglstm"):
        cfg = get_config(arch)
        planner = HybridPlanner(cfg, epoch_model=default_epoch_model(cfg))
        for devices in (64, 256):
            best = planner.best(devices)
            assert best.mp_kind == "pipeline", (arch, devices)
            assert best.schedule != "gpipe", (arch, devices, best.schedule)
            assert best.schedule in SCHEDULE_KINDS


def _max_feasible_micro(cfg, schedule, stages, hbm, *, mini_batch=64,
                        seq_len=4096, remat=False):
    best = 0
    for k in (2, 4, 8, 16, 32, 64):
        if mini_batch % k:
            continue
        mem = per_device_mem_bytes(
            cfg, mp=stages, mp_kind="pipeline", fsdp=1,
            mini_batch=mini_batch, seq_len=seq_len, remat=remat,
            microbatches=k, schedule=schedule)
        if mem <= hbm:
            best = max(best, k)
    return best


@pytest.mark.parametrize("arch", ["gnmt", "biglstm", "llama3_2_1b"])
def test_1f1b_feasible_micro_count_dominates_gpipe(arch):
    """Planner invariant: at every memory budget, 1F1B's max feasible
    micro-batch count >= GPipe's (its activation residency min(K, S) <= K),
    and there exists a budget where it is strictly larger."""
    cfg = get_config(arch)
    stages = 2
    base = per_device_mem_bytes(
        cfg, mp=stages, mp_kind="pipeline", fsdp=1, mini_batch=64,
        seq_len=4096, remat=False, microbatches=2, schedule="gpipe")
    strictly = False
    for frac in (0.5, 0.6, 0.8, 0.9, 1.0, 1.2, 1.5, 2.0):
        hbm = base * frac
        kg = _max_feasible_micro(cfg, "gpipe", stages, hbm)
        kf = _max_feasible_micro(cfg, "1f1b", stages, hbm)
        assert kf >= kg, (arch, frac, kg, kf)
        if kf > kg:
            strictly = True
    assert strictly, f"{arch}: no budget where 1f1b strictly unlocks micros"
